//! The serving engine: a discrete-event loop over one shared cluster,
//! driving instance lifecycles (up → serve → dissolve → reclaim) for any
//! number of concurrently-served models.
//!
//! The engine is *policy-free*: every system-specific decision is delegated
//! to the traits a [`ModelSession`] carries —
//! [`ScalingBackend`](super::backend::ScalingBackend) plans scaling
//! operations, [`RoutingPolicy`](super::policy::RoutingPolicy) (via
//! [`Router`]) places requests, and
//! [`AdmissionPolicy`](super::policy::AdmissionPolicy) moves queued
//! requests into bounded decode slots through each instance's
//! [`DynamicBatcher`] waiting queue. The event loop never matches on
//! `SystemKind`.
//!
//! Serving instances are modelled as processor-sharing queues whose total
//! service rate follows the [`ExecPipeline`] performance model (so an
//! underfed pipeline or a small batch serves slower, exactly as in §4.3).
//! GPU-time cost accounting charges nodes from the moment a scaling
//! operation reserves them (loading time is billed — the reason slow
//! loading costs money in Fig 14). Models share the cluster's nodes
//! (§2.3 multi-tenancy): scale-outs recruit from the same free pool, and
//! per-model host-memory warmth survives GPU reclaim.
//!
//! Residency is owned by one cluster-wide [`MemoryManager`] shared across
//! all tenants (§5): every recruit reserves byte-accurate GPU capacity
//! (pinned while serving), reclaim demotes GPU→host through the manager —
//! which, under bounded host capacity, may evict *another tenant's* warm
//! copy and turn that tenant's next scale-up cold — and scaling plans read
//! warmth and tier-tagged sources from manager queries instead of any
//! per-model bookkeeping.

use super::backend::{ClusterState, LiveSchedule, NodeStatus, ScalingRequest};
use super::batcher::DynamicBatcher;
use super::scaling::{NewInstance, ScalingOutcome, Source};
use super::session::{ModelReport, ModelSession, SessionReport};
use crate::config::{ClusterConfig, DisaggConfig};
use crate::disagg::{plan_kv_stream, DecodeView, DisaggRouter, PrefillView, Role, TwoTierScaler};
use crate::kvcache::{
    ContinuousScheduler, IterScratch, KvGeometry, KvPool, KvVictimAction, PrefixHit, PrefixTable,
    ReqView,
};
use crate::memory::{Demotion, Locality, MemoryManager};
use crate::metrics::RequestMetrics;
use crate::multicast::{BlockId, NodeId};
use crate::pipeline::execution::ExecPipeline;
use crate::pipeline::mode_switch::plan_switch_pipeline;
use crate::sim::event::{EventQueue, TimerId};
use crate::sim::fabric::{Fabric, FabricEvent, FabricOp, FabricUpdate, FlowClass, OpId};
use crate::sim::time::{approx_eq, SimTime, SECS_EPS};
use crate::sim::transfer::Tier;
use crate::trace::{Category, SessionTrace, TraceEvent, Tracer};
use std::collections::{BTreeMap, HashMap, HashSet};

#[derive(Clone, Debug)]
struct ActiveReq {
    idx: usize,
    /// Work done so far in this admission, token units.
    done: f64,
    /// Work needed before the first token (prefill + 1 token).
    w_first: f64,
    /// Total work this admission must execute (stall + remaining tokens).
    w_total: f64,
    first_emitted: bool,
    admitted: SimTime,
    // ---- kvcache-mode bookkeeping (zero/ignored under the legacy fluid
    // model, which this struct must not perturb) ----------------------------
    /// Prefill/recompute/swap work units ahead of decode this admission.
    stall_work: f64,
    /// Tokens generated in *previous* admissions (survive preemption).
    decode_base: usize,
    /// KV blocks currently held *privately* from the instance pool
    /// (shared prefix chunks are owned by the instance's [`PrefixTable`]
    /// and are not counted here).
    kv_blocks: usize,
    /// Planned work rate (units/s) for the current iteration.
    rate: f64,
    /// Whether the planned rate is decode (token-emitting) work.
    decoding: bool,
    // ---- prefix-sharing bookkeeping (all zero when sharing is off,
    // leaving every legacy code path untouched) -----------------------------
    /// The request's prefix group (0 = none / sharing off).
    shared_group: u64,
    /// References held on the group's leading chunks (contiguous from
    /// index 0; includes the CoW tail chunk when attached).
    shared_chunks: u32,
    /// Blocks *not* held privately because a shared chunk covers them —
    /// `shared_chunks` normally, one less under CoW (the tail chunk is
    /// read shared but still costs a private copy block).
    shared_discount: u32,
}

impl ActiveReq {
    /// Total tokens generated so far (kvcache mode).
    fn generated(&self) -> usize {
        self.decode_base + ((self.done - self.stall_work).max(0.0) + 1e-9).floor() as usize
    }
}

/// A serving instance's paged KV pool and its memory-manager charge.
struct InstKv {
    pool: KvPool,
    /// Residency key of the KV arena entries in the [`MemoryManager`].
    key: String,
    /// Per member node: (node, layer fraction, bytes currently charged).
    /// Pipeline stages hold KV shards proportional to their layer range.
    charges: Vec<(NodeId, f64, u64)>,
    /// Last sampled pool utilization (per-instance dedup of the series).
    last_util: f64,
    /// Shared prefix chunks (`Some` only with `[kvcache] prefix_sharing`).
    /// Dies with the instance: pool bytes are released wholesale, so the
    /// table needs no per-chunk teardown.
    prefix: Option<PrefixTable>,
}

struct Inst {
    pipe: ExecPipeline,
    dissolve_at: Option<SimTime>,
    active: Vec<ActiveReq>,
    /// Waiting requests, gated by the model's admission policy.
    queue: DynamicBatcher<usize>,
    last_update: SimTime,
    idle_since: SimTime,
    /// Reclaim probes refused by the scaling policy past the keep-alive
    /// in the current idle period (reset on each new idle); bounded by
    /// [`RECLAIM_PROBE_CAP`] so an ill-behaved policy cannot keep the
    /// event loop alive forever.
    reclaim_probes: u32,
    version: u64,
    token_accum: f64,
    /// Paged KV state (kvcache mode only).
    kv: Option<InstKv>,
    /// Pool membership in disaggregated mode (`None` when colocated).
    role: Option<Role>,
    /// Pending revocable reclaim probes `(timer, fire time)`. Cancelled in
    /// O(1) when the instance dies, so a removed instance leaves no
    /// tombstone events churning the queue to the horizon; the fold of
    /// each cancelled fire time into the engine horizon keeps cost
    /// metering bit-identical to letting the probes pop as no-ops.
    reclaim_timers: Vec<(TimerId, SimTime)>,
    /// Reusable buffer for requests finishing in one advance step.
    scratch_finished: Vec<ActiveReq>,
}

/// Forced-reclaim backstop: after this many policy-refused probes past
/// the keep-alive within one idle period, the instance is reclaimed
/// regardless. Far above any legitimate hold (the shipped policies
/// release within one observation window, a handful of probes).
const RECLAIM_PROBE_CAP: u32 = 64;

/// A displaced request's saved progress, awaiting re-admission.
#[derive(Clone, Copy, Debug)]
struct PreemptedReq {
    generated: usize,
    /// How the KV must be rebuilt at re-admission. `None` when it already
    /// was — a pipeline dissolve prices the rebuild of *all* in-flight
    /// state in its mode-switch stall, so the resumed request owes no
    /// further per-request stall.
    action: Option<KvVictimAction>,
}

/// Per-request KV accounting accumulated until completion.
#[derive(Clone, Copy, Debug, Default)]
struct KvReqStats {
    preemptions: u32,
    recompute_s: f64,
    swap_s: f64,
    wait_s: f64,
}

/// Per-request engine bookkeeping, held in one dense arena indexed by the
/// request's trace index. Replaces seven per-model hash maps: at a million
/// requests the maps dominated the per-iteration profile with rehashing
/// and pointer chasing, while the arena is a single O(1)-indexed slab
/// sized once at `add_model`.
#[derive(Clone, Debug, Default)]
struct ReqState {
    /// First-token emission time (set once; survives completion).
    first_token: Option<SimTime>,
    /// The instance currently holding the request (queued or admitted).
    inst: Option<u64>,
    /// Saved progress awaiting re-admission after displacement.
    preempted: Option<PreemptedReq>,
    /// First instant the waiting request was blocked on KV blocks.
    kv_blocked_since: Option<SimTime>,
    /// KV accounting, folded into `RequestMetrics` at completion.
    kv: KvReqStats,
    // ---- disaggregated mode ----------------------------------------------
    /// Prefill phase completed (cleared at final completion) — routing
    /// sends the request to the decode pool.
    decode_phase: bool,
    /// Hand-off start (prefill completion instant).
    handoff_start: Option<SimTime>,
    /// Finished hand-off stream seconds, folded into metrics at completion.
    stream_s: f64,
}

/// Events carry the index of the model they belong to.
enum Ev {
    Arrival(usize, usize),
    /// Coalesced scaling decision (same-instant arrivals see one decision).
    ScaleCheck(usize),
    InstanceUp(usize, u64),
    InstTick(usize, u64, u64),
    /// Time-triggered admission re-check (e.g. batching max_wait expiry).
    AdmitTick(usize, u64),
    Dissolve(usize, u64),
    DissolveDone(usize, Vec<usize>),
    Reclaim(usize, u64),
    /// Shared-fabric wakeup (version-stamped; stale versions are no-ops).
    Fabric(u64),
    /// Injected permanent node failure.
    NodeFail(NodeId),
    /// Periodic scale-down probe while a model has cancellable recruits.
    CancelCheck(usize),
}

/// How often a model with in-flight cancellable recruits re-evaluates its
/// scaler's `desired` for mid-op scale-down (seconds).
const CANCEL_CHECK_S: f64 = 0.25;

/// One request's KV hand-off stream in flight on the shared fabric
/// (disaggregated mode): prefill finished, the shard streams toward a
/// chosen decode instance as a [`FlowClass::Kv`] operation.
struct KvStream {
    /// Trace index of the request being handed off.
    idx: usize,
    /// Chosen decode instance (re-picked if it dies mid-stream).
    decode_inst: u64,
    /// `(node, block)` deliveries still missing before decode admission.
    needs: HashSet<(NodeId, BlockId)>,
}

/// Per-model disaggregated-serving state. `None` = colocated mode, in
/// which the engine takes zero new branches (bit-identical replay).
struct DisaggRuntime {
    cfg: DisaggConfig,
    router: DisaggRouter,
    /// Decode-tier scaling state; the model's `scaler` field is the
    /// prefill tier (it keeps observing arrivals and TTFT as before).
    tiers: TwoTierScaler,
    /// In-flight KV hand-off streams, keyed by fabric op id.
    streams: HashMap<OpId, KvStream>,
    /// Decode-phase requests with no decode instance to go to yet:
    /// `(idx, Some(src_node))` still owes its KV stream from the prefill
    /// node; `(idx, None)` just needs a queue slot (KV rebuilt locally).
    /// (Per-request hand-off state lives in the [`ReqState`] arena.)
    awaiting: Vec<(usize, Option<NodeId>)>,
}

/// One execute-while-load pipeline awaiting its blocks on the fabric.
struct LivePipeline {
    /// `(node, block)` deliveries still missing.
    needs: HashSet<(NodeId, BlockId)>,
    pipe: ExecPipeline,
}

/// Engine-side bookkeeping for one live fabric operation.
struct LiveOp {
    model: usize,
    /// Mode-switch stall applied to `dest_locals` after op finish.
    switch_stall_s: f64,
    /// Recruits that become local replicas at finish + stall.
    dest_locals: Vec<NodeId>,
    /// Nodes that become local replicas at their own completion.
    local_on_complete: HashSet<NodeId>,
    /// Pipelines awaiting their block assignments, in spawn-priority order.
    pipelines: Vec<LivePipeline>,
    /// Instance ids of pipelines spawned by this op (dissolved at finish).
    spawned_pipes: Vec<u64>,
    /// Cold recruits revocable while untouched.
    recruits: Vec<NodeId>,
    /// The op's finish actions ran; the entry only lingers for watch
    /// nodes (self-loads outlasting the multicast) still completing.
    finished: bool,
}

impl LiveOp {
    /// Drop every pending trigger referencing `node` — revocation, orphan
    /// handling and node failure share this scrub, so any new per-node
    /// trigger must be cleared in exactly one place.
    fn scrub_node(&mut self, node: NodeId) {
        self.dest_locals.retain(|&d| d != node);
        self.local_on_complete.remove(&node);
        self.recruits.retain(|&d| d != node);
        self.pipelines.retain(|p| !p.pipe.nodes().contains(&node));
    }
}

/// Shared-node occupancy: at most one model owns a node's GPU at a time;
/// host-memory warmth lives in the engine's shared [`MemoryManager`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NodeUse {
    Free,
    Loading(usize),
    Serving(usize),
}

/// Per-model mutable state inside the engine.
struct ModelRuntime {
    ms: ModelSession,
    backend_name: String,
    /// This tenant's residency key in the shared [`MemoryManager`]
    /// (per-tenant, so two tenants serving the same spec keep distinct
    /// copies, exactly like the pre-manager per-model warm sets).
    mem_key: String,
    instances: BTreeMap<u64, Inst>,
    next_inst_id: u64,
    /// Global queue when no instance exists yet.
    unrouted: std::collections::VecDeque<usize>,
    /// Dense per-request bookkeeping, indexed by trace index.
    reqs: Vec<ReqState>,
    /// Incrementally maintained `unrouted.len() + Σ instance queue.len()`,
    /// so per-arrival demand sizing stays O(1) instead of re-summing every
    /// instance's queue (verified against the full sum in debug builds).
    queued: usize,
    /// The model's scaling policy (from the session builder, or the
    /// cluster config's `[autoscaler]` section when none was set).
    scaler: Box<dyn super::autoscaler::ScalingPolicy>,
    /// A ScaleCheck event is already queued.
    scale_check_pending: bool,
    /// A CancelCheck event is already queued.
    cancel_check_pending: bool,
    /// The pending CancelCheck probe's `(timer, fire time)`, cancellable
    /// when the model's live ops run out of revocable recruits.
    cancel_check_timer: Option<(TimerId, SimTime)>,
    /// Earliest time the next scaling operation may start (cooldown).
    next_op_at: SimTime,
    last_gpu_count: usize,
    completed: usize,
    partition: crate::model::Partition,
    prefill_ratio: f64,
    /// Instances scheduled to come up, keyed by stash id.
    pending: HashMap<u64, (ExecPipeline, Option<SimTime>)>,
    next_stash_id: u64,
    /// Nodes claimed as GPU-resident sources at t=0 (spawned in `run`).
    initial_gpu_nodes: Vec<NodeId>,
    /// KV block geometry (None = legacy fluid model for this session).
    kv_geom: Option<KvGeometry>,
    /// Iteration-level budgets (consulted only in kvcache mode).
    kv_sched: ContinuousScheduler,
    /// Reusable iteration-planning buffers (kvcache mode): the per-tick
    /// plan allocates nothing in steady state.
    iter_scratch: IterScratch,
    /// Disaggregated prefill/decode state (`None` = colocated mode).
    disagg: Option<DisaggRuntime>,
    /// Session → last routed instance (prefix sharing only): follow-up
    /// turns prefer the instance already holding their session's prefix.
    /// Stale entries (reclaimed instances) fall back to the policy pick.
    session_inst: HashMap<u64, u64>,
}

impl ModelRuntime {
    fn new(mut ms: ModelSession, cluster: &ClusterConfig, tenant: usize) -> Self {
        let p = &ms.params;
        let partition = p.spec.partition(p.n_blocks);
        // Work-units: prefill cost per prompt token relative to one decode
        // token at batch 1 on a local replica.
        let local = ExecPipeline::local(0, &p.spec);
        let decode_tok_s = 1.0 / local.peak_tps(1, &p.spec, &cluster.compute).max(1e-9);
        let prefill_tok_s = p.spec.flops_per_token / (cluster.compute.gpu_tflops * 1e12);
        let prefill_ratio = prefill_tok_s / decode_tok_s;

        let per_inst_rps = local.peak_tps(p.max_batch, &p.spec, &cluster.compute)
            / cluster.compute.avg_output_tokens.max(1.0);
        let keep_alive = SimTime::from_secs(p.keep_alive_s);
        let backend_name = ms.backend.name();
        let mem_key = format!("{}#{tenant}", ms.params.spec.name);
        let kv_geom = KvGeometry::for_model(&ms.params.spec, cluster.kv.block_tokens);
        let kv_sched =
            ContinuousScheduler::new(prefill_ratio, cluster.kv.prefill_budget_tokens as f64);
        let mut scaler = ms
            .scaler
            .take()
            .unwrap_or_else(|| super::autoscaler::scaler_from_config(&cluster.autoscaler));
        scaler.configure(per_inst_rps.max(0.1), keep_alive);
        let disagg = cluster.disagg.map(|cfg| {
            let mut tiers = TwoTierScaler::new(
                super::autoscaler::scaler_from_config(&cluster.autoscaler),
                cfg.decode_drain_mult,
            );
            tiers.configure(per_inst_rps.max(0.1), keep_alive);
            DisaggRuntime { cfg, router: DisaggRouter, tiers, streams: HashMap::new(), awaiting: Vec::new() }
        });
        let n_reqs = ms.trace.requests.len();
        ModelRuntime {
            ms,
            backend_name,
            mem_key,
            instances: BTreeMap::new(),
            next_inst_id: 0,
            unrouted: std::collections::VecDeque::new(),
            reqs: vec![ReqState::default(); n_reqs],
            queued: 0,
            scaler,
            scale_check_pending: false,
            cancel_check_pending: false,
            cancel_check_timer: None,
            next_op_at: SimTime::ZERO,
            last_gpu_count: 0,
            completed: 0,
            partition,
            prefill_ratio,
            pending: HashMap::new(),
            next_stash_id: 1_000_000,
            initial_gpu_nodes: Vec::new(),
            kv_geom,
            kv_sched,
            iter_scratch: IterScratch::default(),
            disagg,
            session_inst: HashMap::new(),
        }
    }
}

/// Record a request's first token: remember the emission time and feed the
/// TTFT observation to the scaling policy. Shared by both advance paths
/// (fluid and kvcache) so the TTFT definition cannot drift between them;
/// takes the runtime's fields split apart because callers hold a mutable
/// borrow of `instances` at the call site.
fn note_first_token(
    reqs: &mut [ReqState],
    trace: &crate::workload::Trace,
    scaler: &mut dyn super::autoscaler::ScalingPolicy,
    tracer: &mut Option<Tracer>,
    m: usize,
    idx: usize,
    now: SimTime,
) {
    reqs[idx].first_token = Some(now);
    let ttft = now.saturating_sub(trace.requests[idx].arrival).as_secs();
    scaler.observe_ttft(now, ttft);
    if let Some(tr) = tracer.as_mut() {
        tr.emit(now, TraceEvent::FirstToken { model: m, req: trace.requests[idx].id });
    }
}

/// One admission attempt against an instance pool: probe the prefix table
/// for `group`'s leading resident run, then attach it (refcount bumps) and
/// acquire the private remainder atomically — pool exhaustion rolls back
/// every bump (the prefix module's contract), so a failed attempt leaves
/// no references behind. Without a table (sharing off) this is exactly the
/// legacy `try_acquire(total)`.
fn kv_probe_attach(
    kv: &mut InstKv,
    group: u64,
    n_full: u32,
    want_tail: bool,
    total: usize,
) -> Option<(PrefixHit, usize)> {
    let hit = match kv.prefix.as_ref() {
        Some(t) if group != 0 => t.probe(group, n_full, want_tail),
        _ => PrefixHit::default(),
    };
    let private = total.saturating_sub(hit.discount() as usize);
    let ok = match kv.prefix.as_mut() {
        Some(t) => t.try_attach(&mut kv.pool, group, hit, private),
        None => kv.pool.try_acquire(private),
    };
    ok.then_some((hit, private))
}

/// The multi-model serving engine. Construct with [`ServingEngine::new`],
/// add models (in priority order for initial node claims), then [`run`].
///
/// [`run`]: ServingEngine::run
pub struct ServingEngine {
    cluster: ClusterConfig,
    q: EventQueue<Ev>,
    node_state: Vec<NodeUse>,
    models: Vec<ModelRuntime>,
    /// Cluster-wide tiered residency, shared across all tenants (§5).
    mem: MemoryManager,
    /// Per-node GPU-cost meter: `Some((model, since))` while a node is
    /// reserved for (loading) or serving a tenant; billed on release.
    node_busy: Vec<Option<(usize, SimTime)>>,
    /// Latest event timestamp seen — the metering horizon at run end.
    horizon: SimTime,
    /// The cluster-wide transfer scheduler every live scaling operation's
    /// sends execute on (shared across tenants — §4.2 under real load).
    fabric: Fabric,
    /// Engine-side state of live fabric operations, by op id.
    live: BTreeMap<OpId, LiveOp>,
    /// Permanently failed nodes (never recruited or spawned on again).
    failed: HashSet<NodeId>,
    /// Failure injections queued before `run` (node, time).
    pending_failures: Vec<(NodeId, SimTime)>,
    /// Last recorded per-model fabric throughput sample (GB/s), to dedup
    /// the utilization series.
    fab_util_last: Vec<f64>,
    /// KV hand-off fabric ops → owning model (disaggregated mode only;
    /// engine-level because fabric updates arrive without a model index).
    kv_ops: HashMap<OpId, usize>,
    /// Last pool role each node served in, for the per-pool GPU·s split
    /// (billing intervals close long after the instance is gone).
    node_role: Vec<Option<Role>>,
    /// Per-model count of nodes in `NodeUse::Loading(m)`, maintained at
    /// every occupancy transition — demand sizing runs once per arrival
    /// instant and must not rescan `node_state` each time.
    loading_nodes: Vec<usize>,
    /// Reusable node set for [`Self::account_gpus`].
    account_scratch: HashSet<NodeId>,
    /// The flight recorder (`None` unless the cluster config arms
    /// `[trace]`). Every hook is gated on `is_some()`/`as_mut()`, so the
    /// off path costs one branch and zero allocation — the same
    /// bit-identical-replay discipline as the kvcache and disagg
    /// subsystems.
    tracer: Option<Tracer>,
}

impl ServingEngine {
    /// An engine over `cluster` with no models registered yet.
    pub fn new(cluster: ClusterConfig) -> Self {
        let node_state = vec![NodeUse::Free; cluster.n_nodes];
        let node_busy = vec![None; cluster.n_nodes];
        let mem = MemoryManager::from_cluster(&cluster);
        let mut fabric = Fabric::new(cluster.network.clone());
        let tracer = cluster.trace.map(Tracer::new);
        if let Some(tr) = &tracer {
            if tr.wants(Category::Fabric) {
                // Flow-level events are recorded inside the fabric (the
                // only layer that knows share changes) and drained into
                // the tracer on every fabric update.
                fabric.enable_recorder();
            }
        }
        let node_role = vec![None; cluster.n_nodes];
        let q = EventQueue::with_kind(cluster.event_queue);
        ServingEngine {
            cluster,
            q,
            node_state,
            models: Vec::new(),
            mem,
            node_busy,
            horizon: SimTime::ZERO,
            fabric,
            live: BTreeMap::new(),
            failed: HashSet::new(),
            pending_failures: Vec::new(),
            fab_util_last: Vec::new(),
            kv_ops: HashMap::new(),
            node_role,
            loading_nodes: Vec::new(),
            account_scratch: HashSet::new(),
            tracer,
        }
    }

    /// Forward a batch of memory-manager demotion reports to the flight
    /// recorder (no-op with tracing off).
    fn trace_demotions(&mut self, t: SimTime, demoted: &[Demotion]) {
        if let Some(tr) = self.tracer.as_mut() {
            for d in demoted {
                tr.emit(
                    t,
                    TraceEvent::MemDemoted {
                        node: d.node,
                        model: d.model.clone(),
                        tier: d.to.label(),
                    },
                );
            }
        }
    }

    /// Inject a permanent node failure at `at`: in-flight transfers
    /// touching the node abort and their operations re-plan from surviving
    /// block-holders; instances on the node die and their requests are
    /// re-routed; the node is never recruited again.
    pub fn inject_failure(&mut self, node: NodeId, at: SimTime) {
        self.pending_failures.push((node, at));
    }

    /// Update a node's occupancy and meter per-node GPU·seconds: a tenant
    /// is billed for a node from the moment a scaling operation reserves
    /// it (loading included — the reason slow loading costs money in
    /// Fig 14) through serving and idle keep-alive, until the node
    /// returns to the free pool. Same-tenant transitions (loading →
    /// serving) keep one open interval.
    fn set_node_use(&mut self, n: usize, u: NodeUse, now: SimTime) {
        if let NodeUse::Loading(prev) = self.node_state[n] {
            self.loading_nodes[prev] -= 1;
        }
        if let NodeUse::Loading(m) = u {
            self.loading_nodes[m] += 1;
        }
        self.node_state[n] = u;
        let owner = match u {
            NodeUse::Free => None,
            NodeUse::Loading(m) | NodeUse::Serving(m) => Some(m),
        };
        if let Some((m, since)) = self.node_busy[n] {
            if owner == Some(m) {
                return; // same tenant: the billing interval keeps running
            }
            let secs = now.saturating_sub(since).as_secs();
            if secs > 0.0 {
                let gpus = self.cluster.node.gpus_per_node.max(1) as f64;
                self.models[m].ms.metrics.record_node_busy(n, secs * gpus);
                // Disaggregated mode splits the same GPU·s by pool role.
                if self.models[m].disagg.is_some() {
                    if let Some(role) = self.node_role[n] {
                        self.models[m]
                            .ms
                            .metrics
                            .record_role_gpu_s(role == Role::Prefill, secs * gpus);
                    }
                }
            }
        }
        self.node_busy[n] = owner.map(|m| (m, now));
    }

    /// The shared residency manager (read-only; inspect before `run`).
    pub fn memory(&self) -> &MemoryManager {
        &self.mem
    }

    /// Register a model: claims its initial GPU-resident and host-memory
    /// source nodes from the cluster's free pool (first-come order),
    /// reserving their bytes in the shared memory manager — nodes whose
    /// managed capacity cannot take the model are skipped. Returns the
    /// model's index.
    pub fn add_model(&mut self, ms: ModelSession) -> usize {
        let m = self.models.len();
        let mut rt = ModelRuntime::new(ms, &self.cluster, m);
        self.mem.register_model(&rt.mem_key, rt.ms.params.spec.bytes);
        if rt.ms.params.ssd_everywhere {
            self.mem.seed_ssd_everywhere(&rt.mem_key);
        }
        self.fab_util_last.push(0.0);
        self.loading_nodes.push(0);
        // One allocation up front instead of doubling growth mid-run.
        rt.ms.metrics.reserve_requests(rt.ms.trace.requests.len());
        let mut want_gpu = rt.ms.params.initial_gpu_sources;
        let mut want_host = rt.ms.params.initial_host_sources;
        for n in 0..self.node_state.len() {
            if self.node_state[n] != NodeUse::Free {
                continue;
            }
            if want_gpu > 0 {
                if let Ok(demoted) = self.mem.reserve_gpu(n, &rt.mem_key, SimTime::ZERO) {
                    self.trace_demotions(SimTime::ZERO, &demoted);
                    self.set_node_use(n, NodeUse::Serving(m), SimTime::ZERO);
                    rt.initial_gpu_nodes.push(n);
                    want_gpu -= 1;
                }
                continue;
            }
            if want_host > 0 {
                if let Ok(demoted) = self.mem.admit_host(n, &rt.mem_key, SimTime::ZERO) {
                    self.trace_demotions(SimTime::ZERO, &demoted);
                    want_host -= 1;
                }
                continue;
            }
            break;
        }
        self.models.push(rt);
        m
    }

    /// Run the event loop to completion and return per-model metrics.
    pub fn run(self) -> SessionReport {
        self.run_traced().0
    }

    /// Run the event loop to completion, also returning the sealed
    /// flight-recorder trace when the cluster config armed one (`None`
    /// otherwise). The [`SessionReport`] is bit-identical whether or not
    /// tracing is on — the recorder only observes.
    pub fn run_traced(mut self) -> (SessionReport, Option<SessionTrace>) {
        // Initial GPU-resident sources serve from t=0.
        for m in 0..self.models.len() {
            let nodes = std::mem::take(&mut self.models[m].initial_gpu_nodes);
            for node in nodes {
                let pipe = ExecPipeline::local(node, &self.models[m].ms.params.spec);
                self.spawn_instance(m, pipe, None, SimTime::ZERO);
            }
            self.account_gpus(m, SimTime::ZERO);
        }
        for m in 0..self.models.len() {
            for (i, r) in self.models[m].ms.trace.requests.iter().enumerate() {
                self.q.push(r.arrival, Ev::Arrival(m, i));
            }
        }
        for (node, at) in std::mem::take(&mut self.pending_failures) {
            self.q.push(at, Ev::NodeFail(node));
        }
        while let Some((t, ev)) = self.q.pop() {
            self.horizon = self.horizon.max(t);
            match ev {
                Ev::Arrival(m, i) => self.on_arrival(t, m, i),
                Ev::ScaleCheck(m) => {
                    self.models[m].scale_check_pending = false;
                    self.maybe_scale(t, m);
                }
                Ev::InstanceUp(m, id) => self.on_instance_up(t, m, id),
                Ev::InstTick(m, id, ver) => self.on_tick(t, m, id, ver),
                Ev::AdmitTick(m, id) => self.try_admit(t, m, id),
                Ev::Dissolve(m, id) => self.on_dissolve(t, m, id),
                Ev::DissolveDone(m, reqs) => {
                    for r in reqs {
                        self.route_request(t, m, r);
                    }
                }
                Ev::Reclaim(m, id) => self.on_reclaim(t, m, id),
                Ev::Fabric(ver) => {
                    let upd = self.fabric.on_wakeup(t, ver);
                    self.handle_fabric_update(t, upd);
                }
                Ev::NodeFail(n) => self.on_node_fail(t, n),
                Ev::CancelCheck(m) => self.on_cancel_check(t, m),
            }
        }
        // Close the cost meters at the simulation horizon: nodes still
        // held (keep-alive floor replicas) bill their final interval, and
        // each tenant's warm host-cache occupancy integral is folded into
        // its metrics.
        let horizon = self.horizon;
        let gpus = self.cluster.node.gpus_per_node.max(1) as f64;
        let models = &mut self.models;
        for (n, slot) in self.node_busy.iter_mut().enumerate() {
            if let Some((m, since)) = slot.take() {
                let secs = horizon.saturating_sub(since).as_secs();
                if secs > 0.0 {
                    models[m].ms.metrics.record_node_busy(n, secs * gpus);
                    if models[m].disagg.is_some() {
                        if let Some(role) = self.node_role[n] {
                            models[m]
                                .ms
                                .metrics
                                .record_role_gpu_s(role == Role::Prefill, secs * gpus);
                        }
                    }
                }
            }
        }
        self.mem.accrue_host(horizon);
        for rt in models.iter_mut() {
            let gb_s = self.mem.host_gb_seconds(&rt.mem_key);
            if gb_s > 0.0 {
                rt.ms.metrics.record_host_gb_seconds(gb_s);
            }
        }
        let events = self.q.popped();
        // Seal the trace before the report build consumes the models
        // (the exporters index events by model name). With tracing off
        // this allocates nothing.
        let trace = self.tracer.take().map(|t| {
            let names = self.models.iter().map(|rt| rt.ms.params.spec.name.clone()).collect();
            t.finish(names, horizon)
        });
        let report = SessionReport {
            models: self
                .models
                .into_iter()
                .map(|rt| ModelReport {
                    model: rt.ms.params.spec.name.clone(),
                    system: rt.backend_name,
                    router: rt.ms.router.policy_name(),
                    scaler: rt.scaler.name(),
                    completed: rt.completed,
                    metrics: rt.ms.metrics,
                })
                .collect(),
            events,
        };
        (report, trace)
    }

    // ---- instance lifecycle ------------------------------------------------

    fn spawn_instance(
        &mut self,
        m: usize,
        pipe: ExecPipeline,
        dissolve_at: Option<SimTime>,
        now: SimTime,
    ) -> u64 {
        // A full local replica is a serveable multicast source; pipeline
        // stages hold only part of the model and never become sources.
        let full_replica = pipe.n_stages() == 1;
        let mem_key = self.models[m].mem_key.clone();
        for &n in &pipe.nodes() {
            if n < self.node_state.len() {
                self.set_node_use(n, NodeUse::Serving(m), now);
                // Usually a refresh of the reservation made at recruit
                // time; scripted (mock) plans may land on unreserved nodes,
                // where a full node is simply not charged.
                if let Ok(demoted) = self.mem.reserve_gpu(n, &mem_key, now) {
                    self.trace_demotions(now, &demoted);
                }
                if full_replica {
                    self.mem.mark_gpu_ready(n, &mem_key);
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.emit(now, TraceEvent::MemPromoted { node: n, model: mem_key.clone() });
                    }
                }
            }
        }
        let md = &mut self.models[m];
        let id = md.next_inst_id;
        md.next_inst_id += 1;
        let weight =
            pipe.service_rate(md.ms.params.max_batch, &md.ms.params.spec, &self.cluster.compute);
        let queue = md.ms.admission.make_queue(md.ms.params.max_batch);
        md.instances.insert(
            id,
            Inst {
                pipe,
                dissolve_at,
                active: Vec::new(),
                queue,
                last_update: now,
                idle_since: now,
                reclaim_probes: 0,
                version: 0,
                token_accum: 0.0,
                kv: None,
                role: None,
                reclaim_timers: Vec::new(),
                scratch_finished: Vec::new(),
            },
        );
        md.ms.router.add_instance(id, weight.max(1e-6));
        if let Some(tr) = self.tracer.as_mut() {
            let p = &md.instances[&id].pipe;
            let (node, stages) = (p.stages[0].node, p.n_stages());
            // `SimTime::MAX` is the live-fabric sentinel: this pipeline
            // activated mid-multicast (execute-while-load).
            let ev = if dissolve_at == Some(SimTime::MAX) {
                TraceEvent::PipelineActivated { model: m, inst: id, node, stages }
            } else {
                TraceEvent::InstanceUp { model: m, inst: id, node, stages }
            };
            tr.emit(now, ev);
        }
        // Disaggregated mode: assign the new instance to a pool. Real
        // multi-stage pipelines always decode (pipelined decode is a
        // decode-pool construct — prefill stays on full local replicas);
        // locals fill whichever pool is further below its wanted size.
        let role = self.models[m].disagg.as_ref().map(|d| {
            let md = &self.models[m];
            if md.instances[&id].pipe.n_stages() > 1 {
                Role::Decode
            } else {
                let np = md.instances.values().filter(|i| i.role == Some(Role::Prefill)).count();
                let nd = md.instances.values().filter(|i| i.role == Some(Role::Decode)).count();
                d.tiers.pick_role(np, nd)
            }
        });
        if let Some(r) = role {
            self.models[m].instances.get_mut(&id).unwrap().role = Some(r);
            let members = self.models[m].instances[&id].pipe.nodes();
            for n in members {
                if n < self.node_role.len() {
                    self.node_role[n] = Some(r);
                }
            }
        }
        // kvcache mode: carve a per-instance paged KV pool out of the
        // manager's remaining GPU headroom on every member node — KV and
        // pinned weights compete for the same per-node byte budget.
        if let Some(geom) = self.models[m].kv_geom {
            let kv = self.build_kv_pool(m, id, geom, now);
            self.models[m].instances.get_mut(&id).unwrap().kv = Some(kv);
        }
        // A fresh decode instance unblocks parked hand-offs: launch their
        // KV streams (or enqueue re-routes whose KV rebuilds locally).
        if role == Some(Role::Decode) {
            let waiting =
                std::mem::take(&mut self.models[m].disagg.as_mut().unwrap().awaiting);
            for (idx, src) in waiting {
                match src {
                    Some(src) => self.launch_kv_stream(now, m, src, idx),
                    None => self.route_disagg(now, m, idx),
                }
            }
        }
        if let Some(d) = dissolve_at {
            // `SimTime::MAX` is the live-fabric sentinel: the pipeline
            // dissolves when its operation finishes (the engine pushes the
            // Dissolve event then), not at a plan-time instant.
            if d != SimTime::MAX {
                self.q.push(d.max(now), Ev::Dissolve(m, id));
            }
        } else {
            self.schedule_reclaim(m, id, now);
        }
        // Drain globally queued requests, then rebalance: a fresh instance
        // must be able to steal queued (not yet admitted) work from
        // overloaded peers — otherwise scaling out never helps requests
        // that arrived before the new capacity.
        while let Some(r) = self.models[m].unrouted.pop_front() {
            self.models[m].queued -= 1;
            self.route_request(now, m, r);
        }
        self.rebalance(now, m);
        self.account_gpus(m, now);
        id
    }

    // ---- paged KV pools (kvcache mode) --------------------------------------

    /// Size and charge a new instance's KV pool: target
    /// `max_batch × blocks_for(max_ctx_tokens)` blocks, clamped to the
    /// smallest per-node headroom across the pipeline's members (each
    /// stage holds the shard of every block matching its layer range).
    /// Zero headroom yields an empty pool — admission then grows it
    /// explicitly or overflows with a counter, never silently.
    fn build_kv_pool(&mut self, m: usize, id: u64, geom: KvGeometry, now: SimTime) -> InstKv {
        let (members, desired, key) = {
            let md = &self.models[m];
            let inst = &md.instances[&id];
            // Coalesce per node: a (scripted) pipeline may put several
            // stages on one node, but the manager keys the whole arena by
            // one string per node — duplicate charge rows would silently
            // desynchronize the byte accounting.
            let mut by_node: std::collections::BTreeMap<NodeId, f64> =
                std::collections::BTreeMap::new();
            for s in 0..inst.pipe.n_stages() {
                *by_node.entry(inst.pipe.stages[s].node).or_insert(0.0) +=
                    inst.pipe.layer_frac(s);
            }
            let members: Vec<(NodeId, f64)> = by_node.into_iter().collect();
            let desired =
                md.ms.params.max_batch.max(1) * geom.blocks_for(self.cluster.kv.max_ctx_tokens);
            (members, desired, format!("__kv__/{}/inst{}", md.mem_key, id))
        };
        let mut blocks = desired;
        for &(n, frac) in &members {
            if frac <= 0.0 || n >= self.mem.n_nodes() {
                continue;
            }
            let per_block = (geom.block_bytes as f64 * frac).ceil().max(1.0) as u64;
            blocks = blocks.min((self.mem.gpu_headroom(n) / per_block) as usize);
        }
        let mut charges: Vec<(NodeId, f64, u64)> = Vec::new();
        let mut ok = blocks > 0;
        if ok {
            for &(n, frac) in &members {
                let bytes = (geom.block_bytes as f64 * frac * blocks as f64).ceil() as u64;
                if bytes == 0 || n >= self.mem.n_nodes() {
                    charges.push((n, frac, 0));
                    continue;
                }
                if let Ok(demoted) = self.mem.reserve_kv(n, &key, bytes, now) {
                    self.trace_demotions(now, &demoted);
                    charges.push((n, frac, bytes));
                } else {
                    // Headroom vanished between sizing and charging (can
                    // only happen through rounding at the boundary): no
                    // pool rather than a half-charged one.
                    for &(pn, _, pb) in &charges {
                        if pb > 0 {
                            self.mem.release_kv(pn, &key);
                        }
                    }
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            blocks = 0;
            charges = members.iter().map(|&(n, f)| (n, f, 0)).collect();
        }
        let prefix = self.cluster.kv.prefix_sharing.then(PrefixTable::new);
        InstKv { pool: KvPool::new(blocks), key, charges, last_util: -1.0, prefix }
    }

    /// Hand a dying instance's KV arena back to the manager. Always runs
    /// *before* the instance's weights are unpinned, so scale-down
    /// releases KV first.
    fn release_kv_pool(&mut self, kv: &InstKv) {
        for &(n, _, bytes) in &kv.charges {
            if bytes > 0 && n < self.mem.n_nodes() {
                self.mem.release_kv(n, &kv.key);
            }
        }
    }

    /// Grow an instance's pool by `extra_blocks`, charging every member
    /// node; rolls back and reports failure when any node lacks headroom.
    fn try_grow_kv(&mut self, now: SimTime, m: usize, id: u64, extra_blocks: usize) -> bool {
        let Some(geom) = self.models[m].kv_geom else { return false };
        let (key, plan): (String, Vec<(NodeId, f64, u64, u64)>) = {
            let inst = self.models[m].instances.get(&id).unwrap();
            let kv = inst.kv.as_ref().unwrap();
            let new_blocks = kv.pool.capacity() + extra_blocks;
            let plan = kv
                .charges
                .iter()
                .map(|&(n, frac, old)| {
                    let new =
                        (geom.block_bytes as f64 * frac * new_blocks as f64).ceil() as u64;
                    (n, frac, old, new.max(old))
                })
                .collect();
            (kv.key.clone(), plan)
        };
        let mut grown: Vec<(NodeId, u64, u64)> = Vec::new();
        let mut ok = true;
        for &(n, _, old, new) in &plan {
            if new == old || n >= self.mem.n_nodes() {
                continue;
            }
            let res = if old == 0 {
                self.mem.reserve_kv(n, &key, new, now)
            } else {
                self.mem.grow_pinned(n, &key, new, now)
            };
            match res {
                Ok(demoted) => {
                    self.trace_demotions(now, &demoted);
                    grown.push((n, old, new));
                }
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            for &(n, old, _) in &grown {
                if old == 0 {
                    self.mem.release_kv(n, &key);
                } else {
                    // Shrinking back to a size that fit moments ago.
                    let _ = self.mem.grow_pinned(n, &key, old, now);
                }
            }
            return false;
        }
        let inst = self.models[m].instances.get_mut(&id).unwrap();
        let kv = inst.kv.as_mut().unwrap();
        kv.pool.grow(extra_blocks);
        for c in kv.charges.iter_mut() {
            if let Some(&(_, _, _, new)) = plan.iter().find(|p| p.0 == c.0) {
                if new > c.2 {
                    c.2 = new;
                }
            }
        }
        true
    }

    /// Pull every queued-but-not-admitted request back and re-route.
    fn rebalance(&mut self, now: SimTime, m: usize) {
        let disagg = self.models[m].disagg.is_some();
        let mut ids: Vec<u64> = self.models[m].instances.keys().copied().collect();
        ids.sort_unstable();
        let mut pool: Vec<usize> = Vec::new();
        for id in &ids {
            self.advance(now, m, *id);
            let md = &mut self.models[m];
            let inst = md.instances.get_mut(id).unwrap();
            // Disaggregated mode: only prefill queues rebalance. A decode
            // queue entry's KV shard already lives (or is landing) on that
            // instance — stealing it would strand the shard.
            if disagg && inst.role != Some(Role::Prefill) {
                continue;
            }
            for p in inst.queue.drain_all() {
                if !disagg {
                    md.ms.router.complete(*id);
                }
                md.reqs[p.item].inst = None;
                md.queued -= 1;
                pool.push(p.item);
            }
        }
        // Oldest first keeps FIFO fairness.
        pool.sort_unstable();
        for idx in pool {
            self.route_request(now, m, idx);
        }
    }

    fn schedule_reclaim(&mut self, m: usize, id: u64, now: SimTime) {
        let md = &self.models[m];
        if md.instances.contains_key(&id) {
            let at = now + SimTime::from_secs(md.ms.params.keep_alive_s);
            let tid = self.q.push_cancelable(at, Ev::Reclaim(m, id));
            let inst = self.models[m].instances.get_mut(&id).unwrap();
            // Prune probes that already fired (their time has passed).
            inst.reclaim_timers.retain(|&(_, t)| t >= now);
            inst.reclaim_timers.push((tid, at));
        }
    }

    /// Revoke a dying instance's pending reclaim probes. Each probe for a
    /// removed instance would pop as a pure no-op (`instances.get` misses)
    /// whose only effect is advancing the metering horizon — folding the
    /// cancelled fire time into the horizon reproduces that effect
    /// exactly, so replay stays bit-identical while the event queue drops
    /// the tombstones in O(1).
    fn cancel_reclaim_timers(&mut self, inst: &Inst) {
        for &(tid, t) in &inst.reclaim_timers {
            if self.q.cancel(tid) {
                self.horizon = self.horizon.max(t);
            }
        }
    }

    fn on_reclaim(&mut self, now: SimTime, m: usize, id: u64) {
        // Decide with shared borrows only: `Some((at, is_hold))` re-checks
        // later, `None` proceeds to reclaim.
        let probe = {
            let md = &self.models[m];
            let Some(inst) = md.instances.get(&id) else { return };
            if !inst.active.is_empty() || !inst.queue.is_empty() {
                // Busy: advance() will schedule a fresh reclaim when it
                // next goes idle. (No self-rescheduling here — it would
                // keep the event queue alive forever.)
                return;
            }
            // Decode-pool instances drain on a stretched keep-alive (their
            // reclaim strands streamed KV of late hand-offs); everything
            // else consults the model's (prefill-tier) policy directly.
            let consent = match (md.disagg.as_ref(), inst.role) {
                (Some(d), Some(Role::Decode)) => d.tiers.should_reclaim_decode(
                    now,
                    inst.idle_since,
                    SimTime::from_secs(md.ms.params.keep_alive_s),
                ),
                _ => md.scaler.should_reclaim(now, inst.idle_since),
            };
            if consent {
                None
            } else {
                let keep_alive = SimTime::from_secs(md.ms.params.keep_alive_s);
                let natural = inst.idle_since + keep_alive;
                if natural > now {
                    // Not idle long enough (the reactive path): re-check
                    // exactly when the keep-alive elapses, preserving the
                    // seed event schedule.
                    Some((natural, false))
                } else if inst.reclaim_probes < RECLAIM_PROBE_CAP {
                    // The policy is deliberately holding capacity past the
                    // keep-alive (SLO violated / mid-ramp): probe again one
                    // keep-alive from now. Holds expire once the policy's
                    // observation windows age out (a `ScalingPolicy`
                    // contract), so legitimate chains end well short of
                    // the cap.
                    Some((now + keep_alive.max(SimTime::from_secs(1.0)), true))
                } else {
                    // A policy that refused this many consecutive probes
                    // has broken the contract; force the reclaim rather
                    // than keep the event loop alive forever.
                    None
                }
            }
        };
        if let Some((at, hold)) = probe {
            let tid = self.q.push_cancelable(at, Ev::Reclaim(m, id));
            let inst = self.models[m].instances.get_mut(&id).unwrap();
            if hold {
                inst.reclaim_probes += 1;
            }
            inst.reclaim_timers.retain(|&(_, t)| t >= now);
            inst.reclaim_timers.push((tid, at));
            return;
        }
        let md = &self.models[m];
        // Keep at least one replica alive so k >= 1 (paper footnote 2):
        // the floor instance simply stays; if another instance appears and
        // this one idles again, a new reclaim will be scheduled.
        let locals = md.instances.values().filter(|i| i.dissolve_at.is_none()).count();
        if locals <= 1 && md.instances[&id].dissolve_at.is_none() {
            return;
        }
        // Disaggregated mode keeps each pool at its configured floor of
        // local replicas (a pool falling to zero would strand its phase).
        if let (Some(d), Some(role)) = (md.disagg.as_ref(), md.instances[&id].role) {
            if md.instances[&id].dissolve_at.is_none() {
                let same = md
                    .instances
                    .values()
                    .filter(|i| i.dissolve_at.is_none() && i.role == Some(role))
                    .count();
                let floor = match role {
                    Role::Prefill => d.cfg.min_prefill,
                    Role::Decode => d.cfg.min_decode,
                };
                if same <= floor.max(1) {
                    return;
                }
            }
        }
        let md = &mut self.models[m];
        let mem_key = md.mem_key.clone();
        let inst = md.instances.remove(&id).unwrap();
        md.ms.router.remove_instance(id);
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::InstanceDown {
                    model: m,
                    inst: id,
                    node: inst.pipe.stages[0].node,
                    reason: "reclaim",
                },
            );
        }
        self.cancel_reclaim_timers(&inst);
        // Scale-down ordering: the KV arena's bytes are released first,
        // so the weights' GPU→host demotion below sees the full headroom.
        if let Some(kv) = &inst.kv {
            self.release_kv_pool(kv);
        }
        for n in inst.pipe.nodes() {
            if n < self.node_state.len() {
                self.set_node_use(n, NodeUse::Free, now);
                // GPU→host demotion through the shared manager: the model
                // stays warm if the node's host tier has room — possibly by
                // evicting another tenant's warm copy (whose next scale-up
                // then goes cold); with too little host capacity this copy
                // itself falls through to SSD.
                let demoted = self.mem.release_gpu(n, &mem_key, now);
                self.trace_demotions(now, &demoted);
            }
        }
        self.account_gpus(m, now);
    }

    // ---- arrivals & routing -------------------------------------------------

    fn on_arrival(&mut self, now: SimTime, m: usize, idx: usize) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::Arrival { model: m, req: self.models[m].ms.trace.requests[idx].id },
            );
        }
        self.models[m].scaler.observe_arrival(now);
        self.route_request(now, m, idx);
        // Defer the scaling decision: same-instant arrivals (a burst) are
        // coalesced into one decision that sees the full backlog.
        if !self.models[m].scale_check_pending {
            self.models[m].scale_check_pending = true;
            self.q.push(now, Ev::ScaleCheck(m));
        }
    }

    fn route_request(&mut self, now: SimTime, m: usize, idx: usize) {
        if self.models[m].disagg.is_some() {
            return self.route_disagg(now, m, idx);
        }
        // Session affinity (prefix sharing only): prefer the instance the
        // session last landed on — that is where its prefix chunks live.
        let prefix_on = self.models[m].kv_geom.is_some() && self.cluster.kv.prefix_sharing;
        let md = &mut self.models[m];
        md.queued += 1;
        let session = md.ms.trace.requests[idx].session_id;
        let preferred = if prefix_on && session != 0 {
            md.session_inst.get(&session).copied()
        } else {
            None
        };
        match md.ms.router.route_preferring(preferred) {
            Some(id) => {
                if prefix_on && session != 0 {
                    md.session_inst.insert(session, id);
                }
                md.reqs[idx].inst = Some(id);
                // Enqueue at the request's arrival time, not `now`: rebalance
                // and dissolve re-route requests through here, and restarting
                // the head-of-line clock would let every scale-out push a
                // batched-admission max_wait deadline further into the future.
                let enqueued = md.ms.trace.requests[idx].arrival;
                md.instances.get_mut(&id).unwrap().queue.push(idx, enqueued);
                if let Some(tr) = self.tracer.as_mut() {
                    tr.emit(
                        now,
                        TraceEvent::Queued { model: m, req: md.ms.trace.requests[idx].id, inst: id },
                    );
                }
                self.try_admit(now, m, id);
            }
            None => md.unrouted.push_back(idx),
        }
    }

    /// Disaggregated routing: prefill-phase requests go to the least
    /// loaded prefill replica, decode-phase requests (re-entering after a
    /// dissolve, failure, or lost stream) to a decode instance by KV
    /// headroom. The session's `RoutingPolicy` is bypassed entirely —
    /// pool placement is the router in this mode.
    fn route_disagg(&mut self, now: SimTime, m: usize, idx: usize) {
        let in_decode = self.models[m].reqs[idx].decode_phase;
        if in_decode {
            // Re-entry: the KV rebuild (if any) is already priced by the
            // request's `preempted` entry; it only needs a decode slot.
            match self.pick_decode_inst(m, idx) {
                Some(d) => self.enqueue_decode(now, m, idx, d),
                None => {
                    self.models[m].disagg.as_mut().unwrap().awaiting.push((idx, None));
                }
            }
            return;
        }
        let md = &mut self.models[m];
        let mut views: Vec<PrefillView> = Vec::new();
        for (&iid, inst) in md.instances.iter() {
            if inst.role != Some(Role::Prefill) {
                continue;
            }
            views.push(PrefillView {
                id: iid,
                queued: inst.queue.len(),
                active: inst.active.len(),
                weight: inst.pipe.service_rate(
                    md.ms.params.max_batch,
                    &md.ms.params.spec,
                    &self.cluster.compute,
                ),
            });
        }
        views.sort_by_key(|v| v.id);
        match md.disagg.as_ref().unwrap().router.pick_prefill(&views) {
            Some(id) => {
                md.reqs[idx].inst = Some(id);
                md.queued += 1;
                let enqueued = md.ms.trace.requests[idx].arrival;
                md.instances.get_mut(&id).unwrap().queue.push(idx, enqueued);
                if let Some(tr) = self.tracer.as_mut() {
                    tr.emit(
                        now,
                        TraceEvent::Queued { model: m, req: md.ms.trace.requests[idx].id, inst: id },
                    );
                }
                self.try_admit(now, m, id);
            }
            None => {
                md.queued += 1;
                md.unrouted.push_back(idx);
            }
        }
    }

    /// Pick a decode instance for request `idx` by KV headroom and queue
    /// depth (the [`DisaggRouter`] contract). `None` when no decode
    /// instance exists yet.
    fn pick_decode_inst(&self, m: usize, idx: usize) -> Option<u64> {
        let md = &self.models[m];
        let d = md.disagg.as_ref().unwrap();
        let mut views: Vec<DecodeView> = md
            .instances
            .iter()
            .filter(|(_, i)| i.role == Some(Role::Decode))
            .map(|(&id, i)| DecodeView {
                id,
                queued: i.queue.len(),
                active: i.active.len(),
                free_kv_blocks: i.kv.as_ref().map_or(0, |kv| kv.pool.free()),
            })
            .collect();
        views.sort_by_key(|v| v.id);
        let need = match md.kv_geom {
            Some(g) => {
                let generated = md.reqs[idx].preempted.map_or(1, |p| p.generated);
                g.blocks_for(md.ms.trace.requests[idx].prompt_tokens + generated)
            }
            None => 0,
        };
        d.router.pick_decode(&views, need)
    }

    /// Queue a decode-phase request on its chosen decode instance.
    fn enqueue_decode(&mut self, now: SimTime, m: usize, idx: usize, inst: u64) {
        let md = &mut self.models[m];
        md.reqs[idx].inst = Some(inst);
        md.queued += 1;
        let enqueued = md.ms.trace.requests[idx].arrival;
        md.instances.get_mut(&inst).unwrap().queue.push(idx, enqueued);
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(now, TraceEvent::Queued { model: m, req: md.ms.trace.requests[idx].id, inst });
        }
        self.try_admit(now, m, inst);
    }

    fn try_admit(&mut self, now: SimTime, m: usize, id: u64) {
        if !self.models[m].instances.contains_key(&id) {
            return;
        }
        self.advance(now, m, id);
        let changed = if self.models[m].kv_geom.is_some() {
            self.admit_kv(now, m, id)
        } else {
            self.admit_fluid(now, m, id)
        };
        let md = &mut self.models[m];
        let Some(inst) = md.instances.get_mut(&id) else { return };
        // Time-triggered admission (e.g. batching max_wait): wake up when
        // the policy's deadline passes.
        let deadline = if inst.queue.is_empty() {
            None
        } else {
            md.ms.admission.next_deadline(&inst.queue)
        };
        if changed {
            self.reschedule(now, m, id);
        }
        if let Some(at) = deadline {
            if at > now {
                self.q.push(at, Ev::AdmitTick(m, id));
            }
        }
    }

    /// Legacy admission: the policy's slot count moves straight into the
    /// processor-sharing batch (the seed engine's exact behavior).
    fn admit_fluid(&mut self, now: SimTime, m: usize, id: u64) -> bool {
        let md = &mut self.models[m];
        let Some(inst) = md.instances.get_mut(&id) else { return false };
        let n = md.ms.admission.admit(now, &inst.queue, inst.active.len(), md.ms.params.max_batch);
        let mut changed = false;
        let admitted = inst.queue.admit(n);
        md.queued -= admitted.len();
        for p in admitted {
            let idx = p.item;
            let r = &md.ms.trace.requests[idx];
            let w_prefill = r.prompt_tokens as f64 * md.prefill_ratio;
            // Disaggregated pools split the request's work: a prefill
            // instance owes prompt ingestion plus the first token; a
            // decode instance resumes a handed-off request for the
            // remaining output (first token already emitted prefill-side).
            let (w_first, w_total, first_emitted) = match inst.role {
                Some(Role::Prefill) => (w_prefill + 1.0, w_prefill + 1.0, false),
                Some(Role::Decode) if md.reqs[idx].decode_phase => {
                    (0.0, r.output_tokens.saturating_sub(1) as f64, true)
                }
                _ => (w_prefill + 1.0, w_prefill + r.output_tokens as f64, false),
            };
            let stall_work = if first_emitted { 0.0 } else { w_prefill };
            inst.active.push(ActiveReq {
                idx,
                done: 0.0,
                w_first,
                w_total,
                first_emitted,
                admitted: now,
                stall_work,
                decode_base: 0,
                kv_blocks: 0,
                rate: 0.0,
                decoding: false,
                shared_group: 0,
                shared_chunks: 0,
                shared_discount: 0,
            });
            if let Some(tr) = self.tracer.as_mut() {
                tr.emit(now, TraceEvent::Admitted { model: m, req: r.id, inst: id });
            }
            changed = true;
        }
        changed
    }

    /// KV-gated admission: the policy grants decode slots, but a request
    /// is seated only when its context's KV blocks are acquirable — FIFO,
    /// one at a time, never skipping the head of the line. Blocked
    /// requests accrue queued-on-KV time.
    fn admit_kv(&mut self, now: SimTime, m: usize, id: u64) -> bool {
        let Some(geom) = self.models[m].kv_geom else { return false };
        let mut changed = false;
        let mut slots = {
            let md = &mut self.models[m];
            let Some(inst) = md.instances.get_mut(&id) else { return false };
            md.ms.admission.admit(now, &inst.queue, inst.active.len(), md.ms.params.max_batch)
        };
        while slots > 0 {
            // The head of the line, the blocks its context needs, and its
            // declared shared prefix (chunked to the block geometry).
            let (idx, need, group, n_full, want_tail, shared_tokens) = {
                let md = &self.models[m];
                let Some(inst) = md.instances.get(&id) else { break };
                let Some(head) = inst.queue.iter().next() else { break };
                let idx = head.item;
                let generated = md.reqs[idx].preempted.map_or(0, |p| p.generated);
                let r = &md.ms.trace.requests[idx];
                let ctx = r.prompt_tokens + generated;
                let sharing = inst.kv.as_ref().is_some_and(|kv| kv.prefix.is_some());
                let group = if sharing { r.prefix_group } else { 0 };
                let shared_tokens =
                    if group != 0 { r.shared_prefix_tokens.min(r.prompt_tokens) } else { 0 };
                let n_full = (shared_tokens / geom.block_tokens) as u32;
                let want_tail = shared_tokens % geom.block_tokens > 0;
                (idx, geom.blocks_for(ctx), group, n_full, want_tail, shared_tokens)
            };
            let Some((hit, private)) =
                self.kv_admit_head(now, m, id, need, group, n_full, want_tail)
            else {
                let md = &mut self.models[m];
                if md.reqs[idx].kv_blocked_since.is_none() {
                    md.reqs[idx].kv_blocked_since = Some(now);
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.emit(
                            now,
                            TraceEvent::KvWaitStart {
                                model: m,
                                req: md.ms.trace.requests[idx].id,
                                inst: id,
                            },
                        );
                    }
                }
                break;
            };
            // Prefill skips tokens whose KV is shared-resident.
            let skip = hit.skipped_tokens(geom.block_tokens, shared_tokens);
            slots -= 1;
            changed = true;
            let md = &mut self.models[m];
            let inst = md.instances.get_mut(&id).unwrap();
            let p = inst.queue.admit(1).pop().expect("admitted head vanished");
            md.queued -= 1;
            debug_assert_eq!(p.item, idx);
            let r = &md.ms.trace.requests[idx];
            let st = &mut md.reqs[idx];
            let pre = st.preempted.take();
            if let Some(t0) = st.kv_blocked_since.take() {
                let waited_s = now.saturating_sub(t0).as_secs();
                st.kv.wait_s += waited_s;
                if let Some(tr) = self.tracer.as_mut() {
                    tr.emit(
                        now,
                        TraceEvent::KvWaitEnd { model: m, req: r.id, inst: id, waited_s },
                    );
                }
            }
            // Time-priced stalls (swap) convert to work units at the
            // request's expected share of the post-admission batch.
            let batch = inst.active.len() + 1;
            let per_req_rate = (inst
                .pipe
                .service_rate(batch, &md.ms.params.spec, &self.cluster.compute)
                / batch as f64)
                .max(1e-9);
            let (decode_base, stall_work) = match pre {
                None => (0, (r.prompt_tokens - skip) as f64 * md.prefill_ratio),
                // Displaced by a pipeline dissolve: KV was rebuilt inside
                // the mode-switch stall; resume decoding directly.
                Some(PreemptedReq { generated, action: None }) => (generated, 0.0),
                Some(pr) => {
                    // Shared-resident prefix tokens never left the
                    // instance at preemption (their chunks stayed in the
                    // table), so neither the recompute replay nor the
                    // host swap covers them — both price `ctx - skip`.
                    let ctx = r.prompt_tokens + pr.generated;
                    match pr.action.unwrap() {
                        KvVictimAction::Recompute => {
                            // Replay prefill over prompt + generated: the
                            // recompute cost lands in this request's latency.
                            let w = (ctx - skip) as f64 * md.prefill_ratio;
                            st.kv.recompute_s += w / per_req_rate;
                            (pr.generated, w)
                        }
                        KvVictimAction::SwapToHost => {
                            let s = crate::kvcache::swap_cost_s(
                                ctx - skip,
                                &md.ms.params.spec,
                                &self.cluster.network,
                            );
                            st.kv.swap_s += s;
                            (pr.generated, s * per_req_rate)
                        }
                    }
                }
            };
            if hit.chunks > 0 {
                md.ms.metrics.record_kv_prefix_hit(hit.chunks as u64, skip as u64, hit.cow);
            }
            let first_emitted = st.first_token.is_some();
            let mut remaining_out = r.output_tokens.saturating_sub(decode_base) as f64;
            // A prefill-pool instance serves only through the first token;
            // the rest of the output belongs to the decode pool.
            if inst.role == Some(Role::Prefill) {
                remaining_out = remaining_out.min(1.0);
            }
            inst.active.push(ActiveReq {
                idx,
                done: 0.0,
                w_first: stall_work + 1.0,
                w_total: stall_work + remaining_out,
                first_emitted,
                admitted: now,
                stall_work,
                decode_base,
                kv_blocks: private,
                rate: 0.0,
                decoding: false,
                shared_group: group,
                shared_chunks: hit.chunks,
                shared_discount: hit.discount(),
            });
            if let Some(tr) = self.tracer.as_mut() {
                tr.emit(now, TraceEvent::Admitted { model: m, req: r.id, inst: id });
            }
        }
        changed
    }

    /// Seat the queue head: probe the shared prefix table, attach the
    /// resident leading run (refcount bumps, rolled back atomically on
    /// pool exhaustion), and acquire private blocks for the remainder —
    /// `total` context blocks minus the shared discount. Under pressure,
    /// cached (refcount-zero) chunks are evicted youngest-first before
    /// giving up. An idle instance whose pool can never seat the head
    /// grows the pool from manager headroom, or — headroom exhausted —
    /// overflows with an explicit counter rather than wedging the line
    /// forever. Returns the committed hit and the private blocks taken;
    /// `None` leaves the head waiting with no references leaked.
    fn kv_admit_head(
        &mut self,
        now: SimTime,
        m: usize,
        id: u64,
        total: usize,
        group: u64,
        n_full: u32,
        want_tail: bool,
    ) -> Option<(PrefixHit, usize)> {
        let must_force = {
            let md = &mut self.models[m];
            let Some(inst) = md.instances.get_mut(&id) else { return None };
            let kv = inst.kv.as_mut().expect("kvcache instance without a pool");
            if let Some(got) = kv_probe_attach(kv, group, n_full, want_tail, total) {
                return Some(got);
            }
            // Pool pressure: reclaim cached chunks youngest-first when
            // that fully covers the shortfall, then retry (the fresh
            // probe inside handles chunks of *this* group going away).
            if let Some(tbl) = kv.prefix.as_mut() {
                let short = total.saturating_sub(kv.pool.free());
                if short > 0 && tbl.cached_blocks() >= short {
                    let freed = tbl.evict_cached(short);
                    kv.pool.release(freed);
                    md.ms.metrics.record_kv_prefix_evicted(freed as u64);
                    if let Some(got) = kv_probe_attach(kv, group, n_full, want_tail, total) {
                        return Some(got);
                    }
                }
            }
            if !inst.active.is_empty() || total <= kv.pool.capacity() {
                return None;
            }
            total - kv.pool.capacity()
        };
        if self.try_grow_kv(now, m, id, must_force) {
            let inst = self.models[m].instances.get_mut(&id).unwrap();
            let kv = inst.kv.as_mut().unwrap();
            if let Some(got) = kv_probe_attach(kv, group, n_full, want_tail, total) {
                return Some(got);
            }
            // Growth landed but the head still does not fit — fall through
            // to the forced-overflow escape hatch below.
        }
        let md = &mut self.models[m];
        let inst = md.instances.get_mut(&id).unwrap();
        let kv = inst.kv.as_mut().unwrap();
        let hit = match kv.prefix.as_ref() {
            Some(t) if group != 0 => t.probe(group, n_full, want_tail),
            _ => PrefixHit::default(),
        };
        let private = total.saturating_sub(hit.discount() as usize);
        if hit.chunks > 0 {
            kv.prefix.as_mut().unwrap().attach_refs(group, hit.chunks);
        }
        let before = kv.pool.overcommit_blocks;
        kv.pool.force_acquire(private);
        let granted = kv.pool.overcommit_blocks - before;
        md.ms.metrics.record_kv_overcommit(granted);
        if granted > 0 {
            if let Some(tr) = self.tracer.as_mut() {
                tr.emit(now, TraceEvent::KvOvercommit { model: m, inst: id, blocks: granted });
            }
        }
        Some((hit, private))
    }

    // ---- progress mechanics -------------------------------------------------

    /// Advance instance `id` up to `now`: the legacy processor-sharing
    /// fluid, or planned per-request iteration rates in kvcache mode.
    fn advance(&mut self, now: SimTime, m: usize, id: u64) {
        if self.models[m].kv_geom.is_some() {
            self.advance_kv(now, m, id);
        } else {
            self.advance_fluid(now, m, id);
        }
    }

    /// Apply iteration-planned rates linearly up to `now` (kvcache mode).
    /// Mid-iteration calls (arrivals, dissolves) see partial progress;
    /// the boundary tick then re-plans.
    fn advance_kv(&mut self, now: SimTime, m: usize, id: u64) {
        let md = &mut self.models[m];
        let Some(inst) = md.instances.get_mut(&id) else { return };
        let dt = (now.saturating_sub(inst.last_update)).as_secs();
        inst.last_update = now;
        if dt <= 0.0 || inst.active.is_empty() {
            return;
        }
        let mut decode_rate = 0.0;
        let block_tokens = md.kv_geom.map_or(0, |g| g.block_tokens);
        let Inst { active, kv, .. } = &mut *inst;
        for a in active.iter_mut() {
            a.done += a.rate * dt;
            if a.decoding {
                decode_rate += a.rate;
            }
            if !a.first_emitted && a.done + 1e-9 >= a.w_first {
                a.first_emitted = true;
                note_first_token(
                    &mut md.reqs,
                    &md.ms.trace,
                    md.scaler.as_mut(),
                    &mut self.tracer,
                    m,
                    a.idx,
                    now,
                );
                // Prefill just completed: publish this request's full
                // prefix chunks, *moving* their blocks from its private
                // holding into the shared table. Publishing here — not
                // at admission — keeps hits honest: no later request
                // skips prefill against KV that was never computed.
                // Chunks a racing peer published first dedup, and the
                // redundant private blocks go straight back to the pool.
                if a.shared_group != 0 && block_tokens > 0 {
                    if let Some(k) = kv.as_mut() {
                        if let Some(tbl) = k.prefix.as_mut() {
                            let r = &md.ms.trace.requests[a.idx];
                            let shared = r.shared_prefix_tokens.min(r.prompt_tokens);
                            let n_full = (shared / block_tokens) as u32;
                            if n_full > a.shared_discount {
                                let out = tbl.publish(a.shared_group, a.shared_discount, n_full);
                                let moved = (out.published + out.deduped) as usize;
                                crate::invariant!(a.kv_blocks >= moved);
                                a.kv_blocks -= moved;
                                a.shared_chunks += out.published + out.deduped;
                                a.shared_discount = n_full;
                                k.pool.release(out.deduped as usize);
                                if out.published > 0 {
                                    md.ms.metrics.record_kv_prefix_published(out.published as u64);
                                }
                            }
                        }
                    }
                }
            }
        }
        // Only decode work emits tokens (prefill/stall work does not).
        let mut token_accum = inst.token_accum + decode_rate * dt;
        let emitted_tokens = token_accum as usize;
        token_accum -= emitted_tokens as f64;
        inst.token_accum = token_accum;
        let mut finished = std::mem::take(&mut inst.scratch_finished);
        let mut i = 0;
        while i < inst.active.len() {
            if inst.active[i].done + 1e-9 >= inst.active[i].w_total {
                finished.push(inst.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        // Completed requests hand their private KV blocks straight back
        // and drop their shared-chunk references — chunks reaching
        // refcount zero stay cached for later hits until pool pressure
        // evicts them.
        if let Some(kv) = inst.kv.as_mut() {
            for f in &finished {
                kv.pool.release(f.kv_blocks);
                if f.shared_chunks > 0 {
                    if let Some(t) = kv.prefix.as_mut() {
                        t.detach(f.shared_group, f.shared_chunks);
                    }
                }
            }
        }
        let went_idle = inst.active.is_empty() && inst.queue.is_empty();
        if went_idle {
            inst.idle_since = now;
            inst.reclaim_probes = 0;
        }
        if emitted_tokens > 0 {
            md.ms.metrics.record_tokens(now, emitted_tokens);
        }
        for f in &finished {
            self.complete_request(now, m, id, f);
        }
        // Hand the buffer back for the next advance (the instance may
        // have died inside a completion hook — then it's simply dropped).
        finished.clear();
        if let Some(inst) = self.models[m].instances.get_mut(&id) {
            inst.scratch_finished = finished;
        }
        if went_idle {
            self.schedule_reclaim(m, id, now);
        }
    }

    /// Advance PS progress of instance `id` up to `now`, emitting tokens
    /// (the seed fluid model, byte-identical when kvcache is off).
    fn advance_fluid(&mut self, now: SimTime, m: usize, id: u64) {
        let md = &mut self.models[m];
        let Some(inst) = md.instances.get_mut(&id) else { return };
        let dt = (now.saturating_sub(inst.last_update)).as_secs();
        inst.last_update = now;
        if dt <= 0.0 || inst.active.is_empty() {
            return;
        }
        let total =
            inst.pipe.service_rate(inst.active.len(), &md.ms.params.spec, &self.cluster.compute);
        let per_req = total / inst.active.len() as f64;
        let mut emitted_tokens = 0usize;
        let mut finished = std::mem::take(&mut inst.scratch_finished);
        let mut token_accum = inst.token_accum + total * dt;
        for a in &mut inst.active {
            a.done += per_req * dt;
            if !a.first_emitted && a.done + 1e-9 >= a.w_first {
                a.first_emitted = true;
                note_first_token(
                    &mut md.reqs,
                    &md.ms.trace,
                    md.scaler.as_mut(),
                    &mut self.tracer,
                    m,
                    a.idx,
                    now,
                );
            }
        }
        emitted_tokens += token_accum as usize;
        token_accum -= emitted_tokens as f64;
        let mut i = 0;
        while i < inst.active.len() {
            if inst.active[i].done + 1e-9 >= inst.active[i].w_total {
                finished.push(inst.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        inst.token_accum = token_accum;
        let went_idle = inst.active.is_empty() && inst.queue.is_empty();
        if went_idle {
            inst.idle_since = now;
            inst.reclaim_probes = 0;
        }
        if emitted_tokens > 0 {
            md.ms.metrics.record_tokens(now, emitted_tokens);
        }
        for f in &finished {
            self.complete_request(now, m, id, f);
        }
        finished.clear();
        if let Some(inst) = self.models[m].instances.get_mut(&id) {
            inst.scratch_finished = finished;
        }
        if went_idle {
            self.schedule_reclaim(m, id, now);
        }
    }

    fn complete_request(&mut self, now: SimTime, m: usize, inst_id: u64, a: &ActiveReq) {
        // Disaggregated mode: "completion" on a prefill-role instance is
        // the end of the prefill phase, not of the request — hand the KV
        // shard off toward the decode pool. Single-token requests are
        // fully served by prefill and fall through to a real completion.
        if self.models[m].disagg.is_some() {
            let role = self.models[m].instances.get(&inst_id).and_then(|i| i.role);
            if role == Some(Role::Prefill)
                && self.models[m].ms.trace.requests[a.idx].output_tokens > 1
            {
                self.start_kv_handoff(now, m, inst_id, a.idx);
                self.try_admit(now, m, inst_id);
                return;
            }
        }
        let md = &mut self.models[m];
        let r = &md.ms.trace.requests[a.idx];
        let st = &mut md.reqs[a.idx];
        let first = st.first_token.unwrap_or(now);
        let kv = std::mem::take(&mut st.kv);
        let stream_s = std::mem::take(&mut st.stream_s);
        st.decode_phase = false;
        st.handoff_start = None;
        st.preempted = None;
        st.kv_blocked_since = None;
        st.inst = None;
        md.ms.metrics.record_request(RequestMetrics {
            id: r.id,
            arrival: r.arrival,
            first_token: first,
            completion: now,
            output_tokens: r.output_tokens,
            kv_wait_s: kv.wait_s,
            kv_preemptions: kv.preemptions,
            kv_recompute_s: kv.recompute_s,
            kv_swap_s: kv.swap_s,
            kv_stream_s: stream_s,
        });
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::Done { model: m, req: r.id, inst: inst_id, tokens: r.output_tokens },
            );
        }
        if md.disagg.is_none() {
            md.ms.router.complete(inst_id);
        }
        md.completed += 1;
        self.try_admit(now, m, inst_id);
    }

    // ---- disaggregated KV hand-off -------------------------------------------

    /// A request's prefill finished on `src_inst`: mark it decode-phase,
    /// stamp the hand-off clock, and launch (or park) its KV stream. The
    /// decode tier's scaler observes the hand-off as its demand signal.
    fn start_kv_handoff(&mut self, now: SimTime, m: usize, src_inst: u64, idx: usize) {
        let src_node = self.models[m].instances[&src_inst].pipe.stages[0].node;
        {
            let md = &mut self.models[m];
            let kv_mode = md.kv_geom.is_some();
            let st = &mut md.reqs[idx];
            st.inst = None;
            if kv_mode {
                // The decode side resumes with the prefill token emitted
                // and no rebuild stall — the KV arrives by stream.
                st.preempted = Some(PreemptedReq { generated: 1, action: None });
            }
            st.decode_phase = true;
            st.handoff_start = Some(now);
            if let Some(tr) = self.tracer.as_mut() {
                tr.emit(
                    now,
                    TraceEvent::HandoffStart {
                        model: m,
                        req: md.ms.trace.requests[idx].id,
                        src_node,
                    },
                );
            }
            md.disagg.as_mut().unwrap().tiers.observe_decode_demand(now);
        }
        self.launch_kv_stream(now, m, src_node, idx);
        // Decode-pool pressure changed: let the two-tier scaler react.
        if !self.models[m].scale_check_pending {
            self.models[m].scale_check_pending = true;
            self.q.push(now, Ev::ScaleCheck(m));
        }
    }

    /// Stream `idx`'s KV shard from `src_node` to a decode instance as a
    /// KV-class flow on the shared fabric, contending with any weight
    /// multicasts in flight. Same-node hand-offs deliver instantly; with
    /// no decode instance up yet the request parks until one spawns.
    fn launch_kv_stream(&mut self, now: SimTime, m: usize, src_node: NodeId, idx: usize) {
        let Some(target) = self.pick_decode_inst(m, idx) else {
            self.models[m].disagg.as_mut().unwrap().awaiting.push((idx, Some(src_node)));
            return;
        };
        let (plan, opts) = {
            let md = &self.models[m];
            let pipe = &md.instances[&target].pipe;
            let ctx = md.ms.trace.requests[idx].prompt_tokens;
            (
                plan_kv_stream(src_node, pipe, ctx, &md.ms.params.spec, md.kv_geom.as_ref()),
                md.ms.params.opts,
            )
        };
        if plan.needs.is_empty() {
            // Fully local hand-off: the shard never touches the fabric.
            self.finish_kv_handoff(now, m, idx, target, false);
            return;
        }
        let initial: Vec<(NodeId, BlockId, Tier)> =
            (0..plan.shard_bytes.len()).map(|j| (src_node, j, Tier::Gpu)).collect();
        let (op, upd) = self.fabric.begin_op(
            now,
            FabricOp {
                model: m,
                class: FlowClass::Kv,
                initial,
                intents: plan.intents,
                loads: vec![],
                block_bytes: plan.shard_bytes,
                opts,
                start_delay: SimTime::ZERO,
                expect_full: vec![],
                watch: vec![],
                ssd_fallback: HashSet::new(),
            },
        );
        self.kv_ops.insert(op, m);
        if let Some(tr) = self.tracer.as_mut() {
            // simlint: allow(D001) — plan.needs is a Vec aliasing the KvOp HashSet field name
            let dests = plan.needs.iter().map(|&(n, _)| n).collect::<HashSet<_>>().len();
            tr.emit(now, TraceEvent::OpBegin { model: m, op, class: "kv", dests });
        }
        self.models[m].disagg.as_mut().unwrap().streams.insert(
            op,
            // simlint: allow(D001) — plan.needs is a Vec aliasing the KvOp HashSet field name
            KvStream { idx, decode_inst: target, needs: plan.needs.iter().copied().collect() },
        );
        self.handle_fabric_update(now, upd);
    }

    /// The KV shard for `idx` is resident decode-side: record the stream
    /// time and enqueue the request on its decode instance (admission may
    /// still gate on a free slot and arena blocks).
    fn finish_kv_handoff(
        &mut self,
        now: SimTime,
        m: usize,
        idx: usize,
        decode_inst: u64,
        networked: bool,
    ) {
        {
            let md = &mut self.models[m];
            if let Some(t0) = md.reqs[idx].handoff_start.take() {
                let secs = now.saturating_sub(t0).as_secs();
                md.reqs[idx].stream_s = secs;
                md.ms.metrics.record_kv_stream(secs, networked);
                if let Some(tr) = self.tracer.as_mut() {
                    tr.emit(
                        now,
                        TraceEvent::HandoffDone {
                            model: m,
                            req: md.ms.trace.requests[idx].id,
                            inst: decode_inst,
                            stream_s: secs,
                            networked,
                        },
                    );
                }
            }
        }
        if self.models[m].instances.contains_key(&decode_inst) {
            self.enqueue_decode(now, m, idx, decode_inst);
        } else {
            // The chosen instance died while the shard streamed: the KV
            // is orphaned — rebuild wherever routing lands it now.
            self.reroute_lost_kv(now, m, idx);
        }
    }

    /// A decode-phase request whose streamed KV is gone (dead target or
    /// dead stream source): price the rebuild and re-route.
    fn reroute_lost_kv(&mut self, now: SimTime, m: usize, idx: usize) {
        let md = &mut self.models[m];
        if md.kv_geom.is_some() {
            let generated = md.reqs[idx].preempted.map_or(1, |p| p.generated);
            md.reqs[idx].preempted =
                Some(PreemptedReq { generated, action: Some(KvVictimAction::Recompute) });
        }
        self.route_disagg(now, m, idx);
    }

    /// Schedule the next progress event. Legacy: earliest threshold
    /// crossing or a coarse tick. kvcache mode: the next iteration
    /// boundary, with per-request rates from the planned budgets.
    fn reschedule(&mut self, now: SimTime, m: usize, id: u64) {
        if self.models[m].kv_geom.is_some() {
            self.plan_kv_iteration(now, m, id);
        } else {
            self.reschedule_fluid(now, m, id);
        }
    }

    /// Plan one iteration (kvcache mode): every decode-phase request gets
    /// one token, prefill-phase requests share the chunked-prefill budget
    /// FIFO, and the iteration's wall time prices the planned work at the
    /// pipeline's service rate.
    fn plan_kv_iteration(&mut self, now: SimTime, m: usize, id: u64) {
        let md = &mut self.models[m];
        let (instances, scratch, kv_sched, ms) =
            (&mut md.instances, &mut md.iter_scratch, &md.kv_sched, &md.ms);
        let Some(inst) = instances.get_mut(&id) else { return };
        inst.version += 1;
        let ver = inst.version;
        if inst.active.is_empty() {
            return;
        }
        scratch.views.clear();
        scratch.views.extend(inst.active.iter().map(|a| ReqView {
            remaining_stall: (a.stall_work - a.done).max(0.0),
            remaining_total: (a.w_total - a.done).max(0.0),
            admitted: a.admitted,
            idx: a.idx,
        }));
        kv_sched.plan_into(scratch);
        let plan = &scratch.plan;
        let rate_total = inst
            .pipe
            .service_rate(inst.active.len(), &ms.params.spec, &self.cluster.compute)
            .max(1e-9);
        let iter_s = (plan.total_work / rate_total).max(1e-6);
        for (a, (w, dec)) in
            inst.active.iter_mut().zip(plan.work.iter().zip(plan.decoding.iter()))
        {
            a.rate = w / iter_s;
            a.decoding = *dec;
        }
        self.q.push(now + SimTime::from_secs(iter_s), Ev::InstTick(m, id, ver));
    }

    /// Legacy threshold-crossing scheduler (seed behavior).
    fn reschedule_fluid(&mut self, now: SimTime, m: usize, id: u64) {
        let md = &mut self.models[m];
        let Some(inst) = md.instances.get_mut(&id) else { return };
        inst.version += 1;
        let ver = inst.version;
        if inst.active.is_empty() {
            return;
        }
        let total =
            inst.pipe.service_rate(inst.active.len(), &md.ms.params.spec, &self.cluster.compute);
        let per_req = (total / inst.active.len() as f64).max(1e-9);
        let mut dt_min = f64::INFINITY;
        for a in &inst.active {
            if !a.first_emitted {
                dt_min = dt_min.min((a.w_first - a.done).max(0.0) / per_req);
            }
            dt_min = dt_min.min((a.w_total - a.done).max(0.0) / per_req);
        }
        let dt = dt_min.clamp(1e-6, 0.05); // ≤50 ms ticks for clean timelines
        self.q.push(now + SimTime::from_secs(dt), Ev::InstTick(m, id, ver));
    }

    fn on_tick(&mut self, now: SimTime, m: usize, id: u64, ver: u64) {
        {
            let Some(inst) = self.models[m].instances.get(&id) else { return };
            if inst.version != ver {
                return;
            }
        }
        self.advance(now, m, id);
        if self.models[m].kv_geom.is_some() {
            // Iteration boundary: grow KV for the tokens just generated,
            // preempting the youngest under pressure, then sample the pool.
            self.kv_enforce(now, m, id);
            let md = &mut self.models[m];
            if let Some(inst) = md.instances.get_mut(&id) {
                if let Some(kv) = inst.kv.as_mut() {
                    let util = kv.pool.utilization();
                    if (util - kv.last_util).abs() > 1e-9 {
                        kv.last_util = util;
                        md.ms.metrics.record_kv_util(now, id, util);
                        if let Some(tr) = self.tracer.as_mut() {
                            tr.emit(now, TraceEvent::KvPressure { model: m, inst: id, util });
                        }
                    }
                }
            }
        }
        self.try_admit(now, m, id);
        self.reschedule(now, m, id);
    }

    /// Make every active request's KV holdings match its context, growing
    /// from the pool and preempting the youngest request when it runs
    /// dry. The sole survivor overflows with an explicit counter instead
    /// of preempting itself forever.
    fn kv_enforce(&mut self, now: SimTime, m: usize, id: u64) {
        let Some(geom) = self.models[m].kv_geom else { return };
        // Single left-to-right pass: positions left of the cursor are
        // already satisfied and stay satisfied — growing a later request
        // never changes an earlier one's need, and a preemption only
        // shifts the satisfied prefix left. O(active + preemptions)
        // instead of a rescan from zero after every block grant.
        let mut i = 0usize;
        loop {
            let (pos, deficit) = {
                let md = &self.models[m];
                let Some(inst) = md.instances.get(&id) else { return };
                if inst.kv.is_none() {
                    return;
                }
                let mut found = None;
                for (p, a) in inst.active.iter().enumerate().skip(i) {
                    let ctx = md.ms.trace.requests[a.idx].prompt_tokens + a.generated();
                    // Shared chunks cover part of the context for free —
                    // only the private remainder must be held.
                    let need = geom.blocks_for(ctx).saturating_sub(a.shared_discount as usize);
                    if need > a.kv_blocks {
                        found = Some((p, need - a.kv_blocks));
                        break;
                    }
                }
                match found {
                    Some(f) => f,
                    None => return,
                }
            };
            {
                let md = &mut self.models[m];
                let inst = md.instances.get_mut(&id).unwrap();
                let kv = inst.kv.as_mut().unwrap();
                if kv.pool.try_acquire(deficit) {
                    inst.active[pos].kv_blocks += deficit;
                    i = pos;
                    continue;
                }
                // Before preempting a peer, reclaim cached (unreferenced)
                // prefix chunks — capacity that costs no running request
                // anything. Referenced chunks are never touched.
                if let Some(tbl) = kv.prefix.as_mut() {
                    let short = deficit.saturating_sub(kv.pool.free());
                    let freed = tbl.evict_cached(short);
                    if freed > 0 {
                        kv.pool.release(freed);
                        md.ms.metrics.record_kv_prefix_evicted(freed as u64);
                        if kv.pool.try_acquire(deficit) {
                            inst.active[pos].kv_blocks += deficit;
                            i = pos;
                            continue;
                        }
                    }
                }
                if inst.active.len() == 1 {
                    // Record only what actually lands beyond capacity
                    // (part of the deficit may fit in remaining free).
                    let before = kv.pool.overcommit_blocks;
                    kv.pool.force_acquire(deficit);
                    inst.active[pos].kv_blocks += deficit;
                    let granted = kv.pool.overcommit_blocks - before;
                    md.ms.metrics.record_kv_overcommit(granted);
                    if granted > 0 {
                        if let Some(tr) = self.tracer.as_mut() {
                            tr.emit(
                                now,
                                TraceEvent::KvOvercommit { model: m, inst: id, blocks: granted },
                            );
                        }
                    }
                    i = pos;
                    continue;
                }
            }
            // The youngest request yields its blocks; its KV is rebuilt
            // on resume per the model's KvSwitch policy.
            let victim = {
                let inst = self.models[m].instances.get(&id).unwrap();
                let order: Vec<(SimTime, usize)> =
                    inst.active.iter().map(|a| (a.admitted, a.idx)).collect();
                ContinuousScheduler::youngest(&order).unwrap()
            };
            self.preempt(now, m, id, victim);
            // `remove(victim)` shifted everything right of the victim left
            // by one; keep the cursor on the same request.
            i = if victim < pos { pos - 1 } else { pos };
        }
    }

    /// Preempt `pos`: release its KV, pick the rebuild action, and put it
    /// back at the head of this instance's waiting queue (LIFO resume).
    fn preempt(&mut self, now: SimTime, m: usize, id: u64, pos: usize) {
        let md = &mut self.models[m];
        let inst = md.instances.get_mut(&id).unwrap();
        let a = inst.active.remove(pos);
        if let Some(kv) = inst.kv.as_mut() {
            kv.pool.release(a.kv_blocks);
            // Drop the victim's shared-chunk references: the chunks stay
            // cached (and are usually re-attached when it re-admits), but
            // they must not be pinned by a request holding no KV.
            if a.shared_chunks > 0 {
                if let Some(t) = kv.prefix.as_mut() {
                    t.detach(a.shared_group, a.shared_chunks);
                }
            }
        }
        // The fraction of an in-progress decode token already flowed into
        // the emission accumulator but is not preserved in `generated` —
        // take it back out so the re-decode after resume is not counted
        // twice. (The accumulator may dip below zero; it nets out against
        // future decode work before anything is emitted.)
        let progressed = (a.done - a.stall_work).max(0.0);
        let frac = (progressed - (progressed + 1e-9).floor()).max(0.0);
        inst.token_accum -= frac;
        let r = &md.ms.trace.requests[a.idx];
        let generated = a.generated().min(r.output_tokens);
        let ctx = r.prompt_tokens + generated;
        // A victim still inside its stall (prefill or a rebuild replay)
        // holds only *partial* KV — there is nothing complete to swap, so
        // it must resume by recomputation regardless of policy. Victims
        // with finished stalls hold KV for exactly `ctx` tokens, which is
        // what the policy's cost comparison (and any swap) is priced on.
        let action = if a.done + 1e-9 < a.stall_work {
            KvVictimAction::Recompute
        } else {
            md.ms.kv_switch.choose(
                ctx,
                &md.ms.params.spec,
                &self.cluster.compute,
                &self.cluster.network,
            )
        };
        let st = &mut md.reqs[a.idx];
        st.preempted = Some(PreemptedReq { generated, action: Some(action) });
        st.kv.preemptions += 1;
        st.kv_blocked_since.get_or_insert(now);
        md.ms.metrics.record_kv_preemption(action == KvVictimAction::SwapToHost);
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::KvPreempted {
                    model: m,
                    req: r.id,
                    inst: id,
                    swapped: action == KvVictimAction::SwapToHost,
                },
            );
        }
        // Original arrival keeps the head-of-line clock honest.
        inst.queue.push_front(a.idx, r.arrival);
        md.queued += 1;
    }

    // ---- scaling -------------------------------------------------------------

    /// Demand sizing shared by the scale-out path and the mid-op
    /// cancellation probe — the two must agree on what "wanted capacity"
    /// means. Returns `(desired, current)` where `current` counts live
    /// instances plus recruits still loading; `desired` folds the
    /// scaler's answer with backlog-driven sizing (each instance absorbs
    /// `max_batch` concurrent decodes).
    fn demand(&mut self, now: SimTime, m: usize) -> (usize, usize) {
        let loading = self.loading_nodes[m];
        crate::invariant_eq!(
            loading,
            self.node_state.iter().filter(|s| **s == NodeUse::Loading(m)).count(),
            "incremental loading-node counter diverged"
        );
        if self.models[m].disagg.is_some() {
            return self.demand_disagg(now, m, loading);
        }
        let md = &mut self.models[m];
        let queued = md.queued;
        crate::invariant_eq!(
            queued,
            md.unrouted.len() + md.instances.values().map(|i| i.queue.len()).sum::<usize>(),
            "incremental queued counter diverged"
        );
        let current = md.instances.len() + loading;
        let by_backlog = if queued > 0 {
            md.instances.len() + queued.div_ceil(md.ms.params.max_batch.max(1))
        } else {
            0
        };
        (md.scaler.desired(now, queued, current).max(by_backlog), current)
    }

    /// Two-tier demand sizing (disaggregated mode): prefill and decode
    /// queue pressure are observed independently — the model's scaler is
    /// the prefill tier, the [`TwoTierScaler`] the decode tier — and the
    /// per-pool wants (floored at the configured pool minimums) are
    /// summed for the recruitment machinery, with the split remembered
    /// for role assignment at spawn time.
    fn demand_disagg(&mut self, now: SimTime, m: usize, loading: usize) -> (usize, usize) {
        let md = &mut self.models[m];
        let max_batch = md.ms.params.max_batch.max(1);
        let mut queued_p = md.unrouted.len();
        let mut queued_d = 0usize;
        let (mut cur_p, mut cur_d) = (0usize, 0usize);
        for i in md.instances.values() {
            match i.role {
                Some(Role::Prefill) => {
                    cur_p += 1;
                    queued_p += i.queue.len();
                }
                Some(Role::Decode) => {
                    cur_d += 1;
                    queued_d += i.queue.len();
                }
                None => {}
            }
        }
        let d = md.disagg.as_mut().unwrap();
        // Hand-offs in flight (streaming or parked) are decode demand.
        queued_d += d.streams.len() + d.awaiting.len();
        let backlog_p = if queued_p > 0 { cur_p + queued_p.div_ceil(max_batch) } else { 0 };
        let backlog_d = if queued_d > 0 { cur_d + queued_d.div_ceil(max_batch) } else { 0 };
        let want_d = d
            .tiers
            .desired_decode(now, queued_d, cur_d)
            .max(backlog_d)
            .max(d.cfg.min_decode);
        let want_p = md
            .scaler
            .desired(now, queued_p, cur_p)
            .max(backlog_p)
            .max(d.cfg.min_prefill);
        d.tiers.set_wants(want_p, want_d);
        (want_p + want_d, cur_p + cur_d + loading)
    }

    fn maybe_scale(&mut self, now: SimTime, m: usize) {
        if now < self.models[m].next_op_at {
            // Cooldown: re-check when the window opens.
            if !self.models[m].scale_check_pending {
                self.models[m].scale_check_pending = true;
                let at = self.models[m].next_op_at;
                self.q.push(at, Ev::ScaleCheck(m));
            }
            return;
        }
        let (desired, current) = self.demand(now, m);
        if desired <= current {
            if desired < current && self.models[m].ms.params.cancel_recruits {
                // The scaler changed its mind while recruits are still in
                // flight: revoke surplus recruits that have not received
                // their first block (they never bill GPU·s).
                self.cancel_surplus_recruits(now, m, current - desired);
            }
            return;
        }
        // Free nodes to recruit (shared across models: first claim wins;
        // failed nodes are never recruited again).
        let free: Vec<NodeId> = (0..self.cluster.n_nodes)
            .filter(|&n| self.node_state[n] == NodeUse::Free && !self.failed.contains(&n))
            .collect();
        let want = (desired - current).min(free.len());
        if want == 0 {
            return;
        }
        let mem_key = self.models[m].mem_key.clone();
        self.models[m].next_op_at = now + SimTime::from_millis(100.0);

        // Locality-driven recruitment (§5), answered by the shared memory
        // manager: host-warm nodes are the most valuable recruits — they
        // self-load AND act as multicast sources — so take them first;
        // cold nodes become multicast destinations.
        let warm_cand: Vec<NodeId> = free
            .iter()
            .copied()
            .filter(|&n| self.mem.locality(n, &mem_key) == Locality::HostMem)
            .collect();
        let cold_cand: Vec<NodeId> = free
            .iter()
            .copied()
            .filter(|&n| self.mem.locality(n, &mem_key) != Locality::HostMem)
            .collect();
        let take_warm = want.min(warm_cand.len());
        let take_cold = (want - take_warm).min(cold_cand.len());
        // Capacity-aware recruitment: every recruit reserves (and pins) the
        // model's bytes in its GPU tier up front; nodes whose managed GPU
        // capacity cannot take the model are skipped.
        let mut recruited_warm: Vec<NodeId> = Vec::new();
        for &n in &warm_cand[..take_warm] {
            if let Ok(demoted) = self.mem.reserve_gpu(n, &mem_key, now) {
                self.trace_demotions(now, &demoted);
                recruited_warm.push(n);
            }
        }
        let mut dests_net: Vec<NodeId> = Vec::new();
        for &n in &cold_cand[..take_cold] {
            if let Ok(demoted) = self.mem.reserve_gpu(n, &mem_key, now) {
                self.trace_demotions(now, &demoted);
                dests_net.push(n);
            }
        }
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::ScalePlan {
                    model: m,
                    current,
                    desired,
                    warm: recruited_warm.len(),
                    cold: dests_net.len(),
                },
            );
        }

        // Sources from the manager: fully-loaded GPU replicas first, then
        // every recruited warm node.
        let mut sources_for_plan: Vec<Source> = self
            .mem
            .gpu_sources(&mem_key)
            .into_iter()
            .map(|n| Source { node: n, tier: Tier::Gpu })
            .collect();
        for &n in &recruited_warm {
            sources_for_plan.push(Source { node: n, tier: Tier::HostMem });
        }
        if sources_for_plan.is_empty() {
            // Cold-start fallback: a dest with an SSD copy self-loads.
            // Checked against the SSD set, not `locality()` — the dest's
            // GPU reservation above already makes its raw locality `Gpu`.
            if let Some(&d) = dests_net.first() {
                if self.mem.node(d).in_ssd(&mem_key) {
                    sources_for_plan.push(Source { node: d, tier: Tier::Ssd });
                }
            }
        }
        if sources_for_plan.is_empty() || (dests_net.is_empty() && recruited_warm.is_empty()) {
            // Nothing to scale from (or to): hand the reservations back —
            // the nodes never held the model, so no demotion happens.
            for &n in recruited_warm.iter().chain(dests_net.iter()) {
                self.mem.cancel_gpu_reservation(n, &mem_key);
            }
            return;
        }
        // Hand the tier-tagged recruitment to the backend; it decides how
        // (and whether) warm recruits multicast, self-load, or both. The
        // residency view lets it pick each node's cheapest local tier.
        let statuses: Vec<NodeStatus> = self
            .node_state
            .iter()
            .map(|s| match s {
                NodeUse::Free => NodeStatus::Free,
                NodeUse::Loading(_) => NodeStatus::Loading,
                NodeUse::Serving(_) => NodeStatus::Serving,
            })
            .collect();
        let residency = self.mem.residency(&mem_key);
        enum Planned {
            Live(LiveSchedule),
            Static(ScalingOutcome),
        }
        let planned = {
            let md = &mut self.models[m];
            let req = ScalingRequest {
                sources: sources_for_plan,
                dests: dests_net.clone(),
                spec: &md.ms.params.spec,
                partition: &md.partition,
                opts: md.ms.params.opts,
                switch: md.ms.params.switch,
            };
            let cs =
                ClusterState { config: &self.cluster, nodes: &statuses, residency: &residency };
            // Live-capable backends execute on the shared fabric; the rest
            // (mocks, Ideal, warm-ups) keep the static precomputed path.
            match md.ms.backend.plan_live(&req, &cs) {
                Some(sched) => Planned::Live(sched),
                None => Planned::Static(md.ms.backend.plan(&req, &cs)),
            }
        };
        let outcome: ScalingOutcome = match planned {
            Planned::Live(sched) => {
                self.launch_live_op(now, m, sched, &dests_net, &recruited_warm, &mem_key);
                return;
            }
            Planned::Static(outcome) => outcome,
        };
        // Recruits the plan actually uses start loading; a recruit the
        // outcome never references (possible with scripted or partial
        // plans — every shipped backend covers all recruits) hands its
        // reservation back instead of leaking a pinned phantom copy.
        let mut referenced: HashSet<NodeId> = HashSet::new();
        for (_, ni) in &outcome.instances {
            match ni {
                NewInstance::Pipeline { pipeline, .. } => referenced.extend(pipeline.nodes()),
                NewInstance::Local { node } => {
                    referenced.insert(*node);
                }
            }
        }
        for &d in dests_net.iter().chain(recruited_warm.iter()) {
            if referenced.contains(&d) {
                self.set_node_use(d, NodeUse::Loading(m), now);
            } else {
                self.mem.cancel_gpu_reservation(d, &mem_key);
            }
        }
        self.account_gpus(m, now);
        for (t, ni) in outcome.instances {
            match ni {
                NewInstance::Pipeline { pipeline, dissolve_at } => {
                    let abs_ready = now + t;
                    let abs_dissolve = now + dissolve_at;
                    let stash = self.stash_pipeline(m, pipeline, Some(abs_dissolve));
                    self.q.push(abs_ready, Ev::InstanceUp(m, stash));
                }
                NewInstance::Local { node } => {
                    // Skip nodes already serving (sources).
                    if matches!(self.node_state.get(node), Some(NodeUse::Serving(_)))
                        && t == SimTime::ZERO
                    {
                        continue;
                    }
                    let stash = self.stash_local(m, node);
                    self.q.push(now + t, Ev::InstanceUp(m, stash));
                }
            }
        }
    }

    // ---- live fabric operations ----------------------------------------------

    /// Launch a [`LiveSchedule`] on the shared fabric: recruits it
    /// references start loading, immediate replicas spawn, the transfer op
    /// registers with the fabric, and — while cancellable recruits are in
    /// flight — a periodic scale-down probe is armed.
    fn launch_live_op(
        &mut self,
        now: SimTime,
        m: usize,
        sched: LiveSchedule,
        dests_net: &[NodeId],
        recruited_warm: &[NodeId],
        mem_key: &str,
    ) {
        // A recruit the schedule never references hands its reservation
        // back (mirrors the static path).
        let mut referenced: HashSet<NodeId> = HashSet::new();
        referenced.extend(sched.immediate.iter().copied());
        // simlint: allow(D001) — sched.local_on_complete is a Vec, not the LiveOp set
        referenced.extend(sched.local_on_complete.iter().copied());
        referenced.extend(sched.dest_locals.iter().copied());
        referenced.extend(sched.recruits.iter().copied());
        for p in &sched.pipelines {
            referenced.extend(p.pipeline.nodes());
        }
        let mut n_dests = 0usize;
        for &d in dests_net.iter().chain(recruited_warm.iter()) {
            if referenced.contains(&d) {
                self.set_node_use(d, NodeUse::Loading(m), now);
                n_dests += 1;
            } else {
                self.mem.cancel_gpu_reservation(d, mem_key);
            }
        }
        self.account_gpus(m, now);
        // Immediate local replicas (GPU-resident sources): skip nodes
        // already serving, exactly as the static path does at t=0.
        for &n in &sched.immediate {
            if matches!(self.node_state.get(n), Some(NodeUse::Serving(_))) {
                continue;
            }
            let stash = self.stash_local(m, n);
            self.q.push(now, Ev::InstanceUp(m, stash));
        }
        // The replan fallback: nodes that could self-repair from local SSD.
        let ssd_fallback: HashSet<NodeId> = (0..self.mem.n_nodes())
            .filter(|&n| !self.failed.contains(&n) && self.mem.node(n).in_ssd(mem_key))
            .collect();
        let pipelines: Vec<LivePipeline> = sched
            .pipelines
            .into_iter()
            .map(|p| LivePipeline {
                needs: p
                    .assignment
                    .iter()
                    .flat_map(|(n, bs)| bs.iter().map(move |&b| (*n, b)))
                    .collect(),
                pipe: p.pipeline,
            })
            .collect();
        let has_recruits = !sched.recruits.is_empty();
        let opts = self.models[m].ms.params.opts;
        let (op, upd) = self.fabric.begin_op(
            now,
            FabricOp {
                model: m,
                class: FlowClass::Weights,
                initial: sched.initial,
                intents: sched.intents,
                loads: sched.loads,
                block_bytes: sched.block_bytes,
                opts,
                start_delay: sched.start_delay,
                expect_full: sched.expect_full,
                watch: sched.watch,
                ssd_fallback,
            },
        );
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(now, TraceEvent::OpBegin { model: m, op, class: "weights", dests: n_dests });
        }
        self.live.insert(
            op,
            LiveOp {
                model: m,
                switch_stall_s: sched.switch_stall_s,
                dest_locals: sched.dest_locals,
                // simlint: allow(D001) — sched.local_on_complete is a Vec (LiveSchedule)
                local_on_complete: sched.local_on_complete.into_iter().collect(),
                pipelines,
                spawned_pipes: Vec::new(),
                recruits: sched.recruits,
                finished: false,
            },
        );
        self.handle_fabric_update(now, upd);
        if has_recruits && self.models[m].ms.params.cancel_recruits {
            self.schedule_cancel_check(now, m);
        }
    }

    /// Apply a [`FabricUpdate`]: schedule the wakeup, record utilization
    /// and replan counters, spawn pipelines whose blocks arrived, locals
    /// for completed nodes, finish operations (dest locals + pipeline
    /// dissolves), and revoke orphaned recruits.
    fn handle_fabric_update(&mut self, now: SimTime, upd: FabricUpdate) {
        // Per-flow telemetry recorded by the fabric since the last update
        // (the recorder is enabled only when the tracer wants it, so this
        // drains an always-empty vec otherwise).
        if let Some(tr) = self.tracer.as_mut() {
            for (t, fe) in self.fabric.drain_recorder() {
                let ev = match fe {
                    FabricEvent::FlowStart { op, src, dst, block, bytes } => {
                        TraceEvent::FlowStart { op, src, dst, block, bytes }
                    }
                    FabricEvent::FlowEnd { op, dst, block } => {
                        TraceEvent::FlowEnd { op, dst, block }
                    }
                    FabricEvent::Reshare { op, dst, block, gbps } => {
                        TraceEvent::FlowReshare { op, dst, block, gbps }
                    }
                };
                tr.emit(t, ev);
            }
        }
        if let Some((t, ver)) = upd.wakeup {
            self.q.push(t, Ev::Fabric(ver));
        }
        if let Some(util) = &upd.util {
            // The list is authoritative: a model absent from it has no
            // transfers on the fabric, so its series drops to zero.
            let mut covered = vec![false; self.fab_util_last.len()];
            for &(m, gbps) in util {
                if m >= self.fab_util_last.len() {
                    continue;
                }
                covered[m] = true;
                if !approx_eq(gbps, self.fab_util_last[m], SECS_EPS) {
                    self.fab_util_last[m] = gbps;
                    self.models[m].ms.metrics.record_fabric_util(now, gbps);
                }
            }
            for m in 0..self.fab_util_last.len() {
                if !covered[m] && !approx_eq(self.fab_util_last[m], 0.0, SECS_EPS) {
                    self.fab_util_last[m] = 0.0;
                    self.models[m].ms.metrics.record_fabric_util(now, 0.0);
                }
            }
        }
        for &op in &upd.replanned {
            if let Some(tr) = self.tracer.as_mut() {
                tr.emit(now, TraceEvent::OpReplanned { op });
            }
            if let Some(lo) = self.live.get(&op) {
                let m = lo.model;
                self.models[m].ms.metrics.record_transfer_replan();
            }
        }
        // KV-stream deliveries → decode hand-off triggers (disagg mode).
        let mut kv_done: Vec<(usize, OpId)> = Vec::new();
        for &(op, node, block) in &upd.deliveries {
            let Some(&km) = self.kv_ops.get(&op) else { continue };
            if let Some(s) =
                self.models[km].disagg.as_mut().and_then(|d| d.streams.get_mut(&op))
            {
                s.needs.remove(&(node, block));
                if s.needs.is_empty() {
                    kv_done.push((km, op));
                }
            }
        }
        for (km, op) in kv_done {
            let s = self.models[km].disagg.as_mut().unwrap().streams.remove(&op).unwrap();
            self.finish_kv_handoff(now, km, s.idx, s.decode_inst, true);
        }
        // Deliveries → execute-while-load pipeline triggers.
        let mut to_spawn: Vec<(OpId, usize, ExecPipeline)> = Vec::new();
        for &(op, node, block) in &upd.deliveries {
            if let Some(lo) = self.live.get_mut(&op) {
                let mut i = 0;
                while i < lo.pipelines.len() {
                    lo.pipelines[i].needs.remove(&(node, block));
                    if lo.pipelines[i].needs.is_empty() {
                        let lp = lo.pipelines.remove(i);
                        to_spawn.push((op, lo.model, lp.pipe));
                    } else {
                        i += 1;
                    }
                }
            }
        }
        for (op, m, pipe) in to_spawn {
            if let Some(id) = self.spawn_live_pipeline(now, m, pipe) {
                if let Some(lo) = self.live.get_mut(&op) {
                    lo.spawned_pipes.push(id);
                }
            }
        }
        // Node completions → locals for self-loading sources/replicas. A
        // finished op lingers only for these; drop it once they drain.
        for &(op, node) in &upd.node_completions {
            let mut spawn: Option<usize> = None;
            let mut drained = false;
            if let Some(lo) = self.live.get_mut(&op) {
                if lo.local_on_complete.remove(&node) {
                    spawn = Some(lo.model);
                }
                drained = lo.finished && lo.local_on_complete.is_empty();
            }
            if drained {
                self.live.remove(&op);
            }
            if let Some(m) = spawn {
                if !self.failed.contains(&node) {
                    let stash = self.stash_local(m, node);
                    self.q.push(now, Ev::InstanceUp(m, stash));
                }
            }
        }
        // Orphaned recruits: no surviving source can complete them.
        for &(op, node) in &upd.orphaned {
            let m = match self.live.get_mut(&op) {
                Some(lo) => {
                    lo.scrub_node(node);
                    lo.model
                }
                None => continue,
            };
            // A spawned execute-while-load pipeline serving on the node
            // dies with it (its other members revert to loading if they
            // still expect deliveries); otherwise the node would return to
            // the free pool while an instance still routed requests to it.
            let ids: Vec<u64> = self.models[m]
                .instances
                .iter()
                .filter(|(_, i)| i.pipe.nodes().contains(&node))
                .map(|(&id, _)| id)
                .collect();
            for id in ids {
                self.kill_instance(now, m, id, node);
            }
            let mem_key = self.models[m].mem_key.clone();
            self.mem.cancel_gpu_reservation(node, &mem_key);
            if !self.failed.contains(&node) {
                // The node did receive bytes: it bills until revocation.
                self.set_node_use(node, NodeUse::Free, now);
            }
            self.account_gpus(m, now);
        }
        // Operation finish: dest locals at finish + stall, then pipeline
        // dissolves (this push order preserves the static tie-break when
        // the stall is zero). The entry survives — marked finished — while
        // watch nodes (self-loads outlasting the multicast) still owe
        // their completions.
        for &(op, contended_s) in &upd.op_completions {
            // KV hand-off streams: their contended flow-seconds fold into
            // the same per-model fabric meter as weight multicasts. A
            // stream whose op drained with deliveries still missing lost
            // its source mid-flight (node failure): the request rebuilds
            // its KV decode-side instead.
            if let Some(&km) = self.kv_ops.get(&op) {
                if contended_s > 0.0 {
                    self.models[km].ms.metrics.record_fabric_contended(contended_s);
                }
                if !self.fabric.op_active(op) {
                    self.kv_ops.remove(&op);
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.emit(now, TraceEvent::OpDone { op, contended_s });
                    }
                    let stranded =
                        self.models[km].disagg.as_mut().and_then(|d| d.streams.remove(&op));
                    if let Some(s) = stranded {
                        if !s.needs.is_empty() {
                            self.reroute_lost_kv(now, km, s.idx);
                        }
                    }
                }
                continue;
            }
            let Some(lo) = self.live.get_mut(&op) else { continue };
            if lo.finished {
                // Drain residual from a lingering finished op: late
                // contention (stray flows, watch-node loads) folds in.
                let m = lo.model;
                if contended_s > 0.0 {
                    self.models[m].ms.metrics.record_fabric_contended(contended_s);
                }
                continue;
            }
            lo.finished = true;
            // The cancellation window closes at finish: remaining
            // recruits are materializing into replicas right now.
            lo.recruits.clear();
            if let Some(tr) = self.tracer.as_mut() {
                tr.emit(now, TraceEvent::OpDone { op, contended_s });
            }
            let m = lo.model;
            let at = now + SimTime::from_secs(lo.switch_stall_s);
            let dest_locals = std::mem::take(&mut lo.dest_locals);
            let spawned_pipes = std::mem::take(&mut lo.spawned_pipes);
            // Drop the entry outright when nothing more can arrive: no
            // watch nodes pending, or the fabric op itself is gone (a
            // drained-without-finish close-out after failures).
            let drained = lo.local_on_complete.is_empty() || !self.fabric.op_active(op);
            if drained {
                self.live.remove(&op);
            }
            if contended_s > 0.0 {
                self.models[m].ms.metrics.record_fabric_contended(contended_s);
            }
            for &d in &dest_locals {
                if self.failed.contains(&d) {
                    continue;
                }
                let stash = self.stash_local(m, d);
                self.q.push(at, Ev::InstanceUp(m, stash));
            }
            for id in spawned_pipes {
                if self.models[m].instances.contains_key(&id) {
                    self.q.push(now, Ev::Dissolve(m, id));
                }
            }
            // This op's recruits just materialized; if no other live op
            // still has revocable recruits, the scale-down probe has
            // nothing left to act on.
            self.retire_cancel_check(m);
        }
    }

    /// Spawn an execute-while-load pipeline the instant its blocks arrive
    /// (the live analogue of `on_instance_up` for scheduled pipelines),
    /// returning the instance id for dissolve-at-finish bookkeeping.
    fn spawn_live_pipeline(&mut self, now: SimTime, m: usize, pipe: ExecPipeline) -> Option<u64> {
        if pipe.nodes().iter().any(|n| self.failed.contains(n)) {
            return None;
        }
        let md = &self.models[m];
        let clash = pipe.nodes().iter().any(|&n| {
            md.instances.values().any(|i| {
                i.dissolve_at.is_none() && i.pipe.nodes().contains(&n) && i.pipe.n_stages() == 1
            })
        });
        if clash {
            return None;
        }
        Some(self.spawn_instance(m, pipe, Some(SimTime::MAX), now))
    }

    /// Arm the periodic mid-op scale-down probe for model `m`. The timer
    /// is revocable: when the last cancellable recruit materializes (or
    /// dies) the probe is retired in O(1) instead of firing as a no-op.
    fn schedule_cancel_check(&mut self, now: SimTime, m: usize) {
        if !self.models[m].cancel_check_pending {
            self.models[m].cancel_check_pending = true;
            let at = now + SimTime::from_secs(CANCEL_CHECK_S);
            let tid = self.q.push_cancelable(at, Ev::CancelCheck(m));
            self.models[m].cancel_check_timer = Some((tid, at));
        }
    }

    /// A live, unfinished op of model `m` still holds a recruit the probe
    /// could actually revoke: not failed, untouched on the fabric. Once
    /// none remain, a probe can do nothing — the scaler's answer cannot
    /// revoke recruits that no longer exist — so re-arming it would only
    /// churn the event queue.
    fn has_revocable_recruits(&self, m: usize) -> bool {
        self.live.iter().any(|(&op, lo)| {
            lo.model == m
                && !lo.finished
                && lo
                    .recruits
                    .iter()
                    .any(|&d| !self.failed.contains(&d) && self.fabric.dest_untouched(op, d))
        })
    }

    /// Disarm the probe once nothing is left to revoke, cancelling its
    /// timer in O(1). The cancelled pop would have been a pure no-op
    /// (`on_cancel_check` returns before consulting the scaler), so
    /// replay stays bit-identical as long as the fire time still folds
    /// into the horizon.
    fn retire_cancel_check(&mut self, m: usize) {
        if self.has_revocable_recruits(m) {
            return;
        }
        if let Some((tid, t)) = self.models[m].cancel_check_timer.take() {
            if self.q.cancel(tid) {
                self.horizon = self.horizon.max(t);
            }
            self.models[m].cancel_check_pending = false;
        }
    }

    /// Periodic probe: while a live op still has cancellable recruits,
    /// re-evaluate the scaler's `desired` and revoke the surplus. The
    /// `desired` consultation is idempotent at a fixed instant (a
    /// [`super::autoscaler::ScalingPolicy`] contract), so these extra
    /// probes never perturb the policy's decisions.
    fn on_cancel_check(&mut self, now: SimTime, m: usize) {
        self.models[m].cancel_check_pending = false;
        self.models[m].cancel_check_timer = None;
        if !self.has_revocable_recruits(m) {
            return;
        }
        let (desired, current) = self.demand(now, m);
        if desired < current {
            self.cancel_surplus_recruits(now, m, current - desired);
        }
        if self.has_revocable_recruits(m) {
            self.schedule_cancel_check(now, m);
        }
    }

    /// Revoke up to `surplus` untouched recruits of model `m`, newest
    /// operation first, last recruit first. A revoked recruit's queued
    /// sends are cancelled on the fabric (the remaining schedule repairs
    /// around it), its GPU reservation is handed back, and its open cost
    /// interval is dropped — revoked before the first block, it never
    /// bills GPU·seconds.
    fn cancel_surplus_recruits(&mut self, now: SimTime, m: usize, surplus: usize) {
        let mut remaining = surplus;
        let op_ids: Vec<OpId> = self
            .live
            .iter()
            .filter(|(_, lo)| lo.model == m)
            .map(|(&id, _)| id)
            .rev()
            .collect();
        'ops: for opid in op_ids {
            loop {
                if remaining == 0 {
                    break 'ops;
                }
                let victim = match self.live.get(&opid) {
                    Some(lo) => lo
                        .recruits
                        .iter()
                        .rev()
                        .copied()
                        .find(|&d| {
                            !self.failed.contains(&d) && self.fabric.dest_untouched(opid, d)
                        }),
                    None => None,
                };
                let Some(node) = victim else { break };
                self.live.get_mut(&opid).unwrap().scrub_node(node);
                let upd = self.fabric.cancel_dest(now, opid, node);
                let mem_key = self.models[m].mem_key.clone();
                self.mem.cancel_gpu_reservation(node, &mem_key);
                // Refund: the open cost interval is dropped un-billed.
                self.node_busy[node] = None;
                if let NodeUse::Loading(lm) = self.node_state[node] {
                    self.loading_nodes[lm] -= 1;
                }
                self.node_state[node] = NodeUse::Free;
                self.models[m].ms.metrics.record_transfer_cancel();
                if let Some(tr) = self.tracer.as_mut() {
                    tr.emit(now, TraceEvent::RecruitCancelled { model: m, node });
                }
                self.handle_fabric_update(now, upd);
                self.account_gpus(m, now);
                remaining -= 1;
                if !self.live.contains_key(&opid) {
                    break; // cancellation completed (or drained) the op
                }
            }
        }
    }

    /// Permanent node failure: abort + re-plan fabric work, tear down
    /// instances on the node (their requests re-route and restart), hand
    /// back its memory claims, and blacklist it from future recruitment.
    fn on_node_fail(&mut self, now: SimTime, node: NodeId) {
        if node >= self.node_state.len() || !self.failed.insert(node) {
            return;
        }
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(now, TraceEvent::NodeFailed { node });
        }
        let upd = self.fabric.fail_node(now, node);
        // Scrub the dead node from every live op's pending triggers before
        // applying the update (so nothing spawns on it). An already
        // finished op that was lingering only for this node's completion
        // has nothing left to wait for — drop it, or its entry (and the
        // cancellation probe keyed on it) would leak to the horizon.
        for lo in self.live.values_mut() {
            lo.scrub_node(node);
        }
        self.live.retain(|_, lo| !(lo.finished && lo.local_on_complete.is_empty()));
        // Tear down instances (local replicas and pipelines) on the node.
        for m in 0..self.models.len() {
            let ids: Vec<u64> = self.models[m]
                .instances
                .iter()
                .filter(|(_, i)| i.pipe.nodes().contains(&node))
                .map(|(&id, _)| id)
                .collect();
            for id in ids {
                self.kill_instance(now, m, id, node);
            }
        }
        // Whoever still owns the node releases it (billed until failure).
        match self.node_state[node] {
            NodeUse::Loading(m) | NodeUse::Serving(m) => {
                let mem_key = self.models[m].mem_key.clone();
                self.mem.cancel_gpu_reservation(node, &mem_key);
                self.set_node_use(node, NodeUse::Free, now);
                self.account_gpus(m, now);
            }
            NodeUse::Free => {}
        }
        self.handle_fabric_update(now, upd);
        // Let every scaler react to the lost capacity.
        for m in 0..self.models.len() {
            if !self.models[m].scale_check_pending {
                self.models[m].scale_check_pending = true;
                self.q.push(now, Ev::ScaleCheck(m));
            }
        }
    }

    /// Tear down an instance whose node died: queued and in-flight
    /// requests re-route (in-flight work restarts — kvcache mode resumes
    /// by recomputation), KV and weight claims are released on surviving
    /// member nodes, and the failed node itself is left to the caller.
    ///
    /// Fluid-mode re-routed requests restart with the legacy dissolve
    /// semantics: a request past its first token re-emits it after
    /// re-admission, updating its first-token record and feeding the scaler a
    /// fresh TTFT observation — deliberately identical to the seed
    /// engine's mode-switch re-route path (kvcache mode tracks emission
    /// exactly and never double-counts).
    fn kill_instance(&mut self, now: SimTime, m: usize, id: u64, failed_node: NodeId) {
        self.advance(now, m, id);
        let md = &mut self.models[m];
        let Some(inst) = md.instances.remove(&id) else { return };
        md.ms.router.remove_instance(id);
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::InstanceDown {
                    model: m,
                    inst: id,
                    node: inst.pipe.stages[0].node,
                    reason: "failure",
                },
            );
        }
        md.queued -= inst.queue.len();
        let kv_mode = md.kv_geom.is_some();
        let mut to_reroute: Vec<usize> = inst.queue.iter().map(|p| p.item).collect();
        if kv_mode && md.disagg.is_some() {
            // Queued decode-phase requests on a dead decode instance lost
            // their streamed KV with it: their no-stall resume entry must
            // become a priced rebuild.
            for p in inst.queue.iter() {
                if let Some(pr) = md.reqs[p.item].preempted.as_mut() {
                    pr.action = Some(KvVictimAction::Recompute);
                }
            }
        }
        for a in &inst.active {
            let r = &md.ms.trace.requests[a.idx];
            if kv_mode {
                let generated = a.generated().min(r.output_tokens);
                md.reqs[a.idx].preempted =
                    Some(PreemptedReq { generated, action: Some(KvVictimAction::Recompute) });
            }
            to_reroute.push(a.idx);
        }
        for idx in &to_reroute {
            md.reqs[*idx].inst = None;
        }
        let mem_key = md.mem_key.clone();
        self.cancel_reclaim_timers(&inst);
        if let Some(kv) = &inst.kv {
            self.release_kv_pool(kv);
        }
        for n in inst.pipe.nodes() {
            if n >= self.node_state.len() || n == failed_node {
                continue;
            }
            // A surviving member that is still an in-flight destination of
            // a live scaling op goes back to Loading (same tenant, the
            // billing interval continues) and keeps its pinned reservation
            // for the deliveries still coming; only members with no
            // pending role return to the free pool.
            let still_loading = self.live.values().any(|lo| {
                lo.model == m
                    && (lo.dest_locals.contains(&n) || lo.local_on_complete.contains(&n))
            });
            if still_loading {
                self.set_node_use(n, NodeUse::Loading(m), now);
                self.mem.clear_gpu_ready(n, &mem_key);
            } else {
                self.set_node_use(n, NodeUse::Free, now);
                let demoted = self.mem.release_gpu(n, &mem_key, now);
                self.trace_demotions(now, &demoted);
            }
        }
        for idx in to_reroute {
            self.route_request(now, m, idx);
        }
        self.account_gpus(m, now);
    }

    // Pending instance stash: instances created at InstanceUp time.
    fn stash_pipeline(&mut self, m: usize, pipe: ExecPipeline, dissolve: Option<SimTime>) -> u64 {
        let md = &mut self.models[m];
        let id = md.next_stash_id;
        md.next_stash_id += 1;
        md.pending.insert(id, (pipe, dissolve));
        id
    }

    fn stash_local(&mut self, m: usize, node: NodeId) -> u64 {
        let md = &mut self.models[m];
        let id = md.next_stash_id;
        md.next_stash_id += 1;
        let pipe = ExecPipeline::local(node, &md.ms.params.spec);
        md.pending.insert(id, (pipe, None));
        id
    }

    fn on_instance_up(&mut self, now: SimTime, m: usize, stash_id: u64) {
        let md = &mut self.models[m];
        let Some((pipe, dissolve)) = md.pending.remove(&stash_id) else { return };
        // An instance scheduled before a node failure must not come up on
        // the dead node (the event outlived the failure).
        if pipe.nodes().iter().any(|n| self.failed.contains(n)) {
            return;
        }
        // A node may have been reused; only bring up if its nodes aren't
        // already serving via another live instance of this model.
        let clash = pipe.nodes().iter().any(|&n| {
            md.instances.values().any(|i| {
                i.dissolve_at.is_none() && i.pipe.nodes().contains(&n) && i.pipe.n_stages() == 1
            })
        });
        if clash && dissolve.is_some() {
            return; // pipeline superseded by a local replica already up
        }
        self.spawn_instance(m, pipe, dissolve, now);
    }

    fn on_dissolve(&mut self, now: SimTime, m: usize, id: u64) {
        {
            let Some(inst) = self.models[m].instances.get(&id) else { return };
            if inst.dissolve_at.is_none() {
                return;
            }
        }
        self.advance(now, m, id);
        let md = &mut self.models[m];
        let inst = md.instances.remove(&id).unwrap();
        let outstanding = md.ms.router.remove_instance(id).unwrap_or(0);
        let _ = outstanding;
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(
                now,
                TraceEvent::InstanceDown {
                    model: m,
                    inst: id,
                    node: inst.pipe.stages[0].node,
                    reason: "dissolve",
                },
            );
        }
        // Mode switch: redistribute in-flight + queued requests with the KV
        // rebuild stall.
        md.queued -= inst.queue.len();
        let kv_mode = md.kv_geom.is_some();
        let mut to_reroute: Vec<usize> = inst.queue.iter().map(|p| p.item).collect();
        if kv_mode && md.disagg.is_some() {
            // Queued decode-phase requests dissolve with their streamed KV
            // (only in-flight state is rebuilt inside the switch stall):
            // their resume entry becomes a priced rebuild.
            for p in inst.queue.iter() {
                if let Some(pr) = md.reqs[p.item].preempted.as_mut() {
                    pr.action = Some(KvVictimAction::Recompute);
                }
            }
        }
        let mut in_flight: Vec<(u64, usize)> = Vec::new();
        for a in &inst.active {
            let r = &md.ms.trace.requests[a.idx];
            // kvcache mode tracks real generated tokens; the fluid model
            // approximates context with raw work units (seed behavior).
            let ctx = if kv_mode {
                let generated = a.generated().min(r.output_tokens);
                // The mode-switch stall below prices rebuilding this
                // request's KV, so it resumes with its progress intact and
                // owes no further per-request stall (`action: None`) —
                // already-emitted tokens are never decoded (or counted)
                // twice.
                md.reqs[a.idx].preempted = Some(PreemptedReq { generated, action: None });
                r.prompt_tokens + generated
            } else {
                r.prompt_tokens + a.done.floor() as usize
            };
            in_flight.push((r.id, ctx));
            to_reroute.push(a.idx);
        }
        for idx in &to_reroute {
            md.reqs[*idx].inst = None;
        }
        // Mode-switch stall priced from the pipeline's actual per-stage
        // KV shard bytes (uneven stages ship uneven shards).
        let stall = plan_switch_pipeline(
            &in_flight,
            &inst.pipe,
            &md.ms.params.spec,
            &self.cluster.compute,
            &self.cluster.network,
            Some(md.ms.params.switch),
        )
        .stall_s;
        let mem_key = md.mem_key.clone();
        self.cancel_reclaim_timers(&inst);
        // KV shards die with the pipeline (before any weight accounting).
        if let Some(kv) = &inst.kv {
            self.release_kv_pool(kv);
        }
        // A dissolving pipeline's nodes are mid-mode-switch: nothing
        // serveable there until their local replicas spawn, so they must
        // not linger as multicast sources. (No-op for real multi-stage
        // pipelines, which are never sources; guards scripted plans.)
        for n in inst.pipe.nodes() {
            if n < self.node_state.len() {
                self.mem.clear_gpu_ready(n, &mem_key);
            }
        }
        self.q
            .push(now + SimTime::from_secs(stall), Ev::DissolveDone(m, to_reroute));
        self.account_gpus(m, now);
    }

    // ---- accounting ----------------------------------------------------------

    /// Record model `m`'s GPU footprint: nodes serving one of its instances
    /// plus nodes loading it.
    fn account_gpus(&mut self, m: usize, now: SimTime) {
        let busy = &mut self.account_scratch;
        busy.clear();
        let md = &self.models[m];
        for inst in md.instances.values() {
            for n in inst.pipe.nodes() {
                busy.insert(n);
            }
        }
        for (n, st) in self.node_state.iter().enumerate() {
            if *st == NodeUse::Loading(m) {
                busy.insert(n);
            }
        }
        let gpus = busy.len() * self.cluster.node.gpus_per_node.max(1);
        let md = &mut self.models[m];
        if gpus != md.last_gpu_count {
            md.last_gpu_count = gpus;
            md.ms.metrics.record_gpu_alloc(now, gpus);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::coordinator::backend::MockBackend;
    use crate::coordinator::session::ServingSession;
    use crate::model::ModelSpec;
    use crate::util::rng::Rng;
    use crate::workload;

    fn burst(n: usize) -> crate::workload::Trace {
        let mut rng = Rng::new(42);
        workload::burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng)
    }

    fn cluster(n: usize) -> ClusterConfig {
        let mut c = ClusterConfig::testbed1();
        c.n_nodes = n;
        c
    }

    /// Scripted lifecycle on a cold cluster: the mock backend brings up one
    /// short-lived pipeline over nodes 0–1 plus local replicas at its
    /// dissolve time — the engine must run up → serve → dissolve → reclaim
    /// without any real multicast plan, and every token served before t=1.0
    /// can only have come from the scripted pipeline.
    #[test]
    fn mock_backend_drives_full_lifecycle() {
        let spec = ModelSpec::llama2_13b();
        let part = spec.partition(crate::model::DEFAULT_BLOCKS);
        let half = part.n_blocks() / 2;
        let pipe_assignment: Vec<(NodeId, Vec<usize>)> = vec![
            (0, (0..half).collect()),
            (1, (half..part.n_blocks()).collect()),
        ];
        let pipeline = ExecPipeline::from_assignment(&pipe_assignment, &part);
        let mut outcome = ScalingOutcome::default();
        outcome.instances.push((
            SimTime::from_secs(0.2),
            NewInstance::Pipeline { pipeline, dissolve_at: SimTime::from_secs(1.0) },
        ));
        outcome.instances.push((SimTime::from_secs(1.0), NewInstance::Local { node: 0 }));
        outcome.instances.push((SimTime::from_secs(1.0), NewInstance::Local { node: 1 }));
        outcome.finish = SimTime::from_secs(1.0);
        outcome.nodes_loading.push((0, SimTime::from_secs(1.0)));
        outcome.nodes_loading.push((1, SimTime::from_secs(1.0)));

        let report = ServingSession::builder()
            .cluster(cluster(4))
            .model(spec)
            .backend(Box::new(MockBackend::new(vec![outcome])))
            .max_batch(4)
            .keep_alive(2.0)
            .initial_gpu_sources(0) // cold: nothing serves until the mock plan
            .trace(burst(8))
            .run();
        let r = &report.models[0];
        assert_eq!(r.system, "mock");
        assert_eq!(r.metrics.requests.len(), 8, "all requests must complete");
        // Up → serve: nothing can emit before the pipeline at t=0.2, and
        // anything before the t=1.0 locals proves the pipeline served.
        let first = r.metrics.requests.iter().map(|q| q.first_token).min().unwrap();
        assert!(first >= SimTime::from_secs(0.2), "served before any instance was up");
        assert!(
            first < SimTime::from_secs(1.0),
            "execute-while-load pipeline never served (first token at {first})"
        );
        // Dissolve → reclaim: the burst drains, the keep-alive floor holds
        // one replica, so allocation must fall back by the horizon.
        let series = r.metrics.gpu_series(5.0, 60.0);
        let last = series.last().unwrap().1;
        assert!(last <= 2, "no scale-in after mock lifecycle: {series:?}");
    }

    /// Disaggregated mode end-to-end (fluid serving model): the pools
    /// split, prefill completions hand off to decode instances, every
    /// request still completes, and the per-pool GPU·s split is
    /// populated on both sides.
    #[test]
    fn disagg_mode_serves_with_split_pools() {
        let mut c = cluster(6);
        c.disagg = Some(crate::config::DisaggConfig::default());
        let report = ServingSession::builder()
            .cluster(c)
            .model(ModelSpec::llama2_13b())
            .max_batch(4)
            .trace(burst(12))
            .run();
        let r = &report.models[0];
        assert_eq!(r.completed, 12, "disagg mode dropped requests");
        assert_eq!(r.metrics.requests.len(), 12);
        assert!(r.metrics.prefill_gpu_s > 0.0, "prefill pool billed no GPU time");
        assert!(r.metrics.decode_gpu_s > 0.0, "decode pool billed no GPU time");
        // Every multi-token request crossed the pools, so each carries a
        // (possibly zero, if same-node) non-negative stream time.
        assert!(r.metrics.requests.iter().all(|q| q.kv_stream_s >= 0.0));
    }

    /// Disaggregated mode under the paged-KV serving model: hand-offs
    /// stream real shard bytes between pools and the per-request
    /// `kv_stream_s` is recorded for networked transfers.
    #[test]
    fn disagg_kv_mode_streams_shards() {
        let mut c = cluster(6);
        c.disagg = Some(crate::config::DisaggConfig::default());
        let report = ServingSession::builder()
            .cluster(c)
            .model(ModelSpec::llama2_13b())
            .kv_block_tokens(16)
            .max_batch(4)
            .trace(burst(10))
            .run();
        let r = &report.models[0];
        assert_eq!(r.completed, 10, "disagg kv mode dropped requests");
        assert!(
            r.metrics.kv_streams > 0,
            "no networked KV hand-off streams despite split pools"
        );
        assert!(r.metrics.kv_stream_flow_s > 0.0, "streams recorded no flow time");
    }

    /// `add_model` routes all residency through the shared MemoryManager:
    /// initial GPU sources are reserved (pinned), SSD is seeded everywhere,
    /// and tenants get distinct residency keys.
    #[test]
    fn add_model_registers_residency_with_manager() {
        let mut eng = ServingEngine::new(cluster(4));
        let a = eng.add_model(crate::coordinator::session::ModelSession::for_test(
            ModelSpec::llama2_13b(),
            Box::new(MockBackend::new(vec![])),
            burst(1),
        ));
        let b = eng.add_model(crate::coordinator::session::ModelSession::for_test(
            ModelSpec::llama2_7b(),
            Box::new(MockBackend::new(vec![])),
            burst(1),
        ));
        assert_eq!((a, b), (0, 1));
        let mem = eng.memory();
        // First-come claims: tenant 0 on node 0, tenant 1 on node 1.
        assert_eq!(mem.locality(0, "llama2-13b#0"), Locality::Gpu);
        assert_eq!(mem.locality(1, "llama2-7b#1"), Locality::Gpu);
        // Pinned: a serving replica must not be evictable.
        assert!(mem.node(0).gpu_pinned("llama2-13b#0"));
        // ssd_everywhere seeds the lower tier on every node.
        assert_eq!(mem.locality(3, "llama2-13b#0"), Locality::Ssd);
        mem.assert_invariants();
    }

    /// Cold start with no GPU and no warm sources: the SSD fallback must
    /// still let the backend plan. Regression: the fallback has to consult
    /// the SSD set directly, because the recruits' own GPU reservations
    /// shadow their raw locality by the time sources are assembled.
    #[test]
    fn cold_start_scales_from_ssd_fallback() {
        let report = ServingSession::builder()
            .cluster(cluster(4))
            .model(ModelSpec::llama2_13b())
            .system(crate::coordinator::SystemKind::ServerlessLlm)
            .initial_gpu_sources(0)
            .max_batch(8)
            .trace(burst(10))
            .run();
        assert_eq!(report.models[0].completed, 10, "cold SSD start must serve all requests");
    }

    /// With a GPU budget too small for the model, no node can ever be
    /// recruited: the engine must decline to serve rather than
    /// oversubscribe (and must not wedge or panic).
    #[test]
    fn gpu_capacity_too_small_declines_to_serve() {
        let mut c = cluster(4);
        c.node.gpu_capacity_bytes = 1_000_000_000; // 1 GB < 26 GB model
        let report = ServingSession::builder()
            .cluster(c)
            .model(ModelSpec::llama2_13b())
            .system(crate::coordinator::SystemKind::ServerlessLlm)
            .trace(burst(5))
            .run();
        assert_eq!(report.models[0].completed, 0, "nothing can fit, nothing may serve");
    }

    /// An empty scripted outcome must not wedge the engine: the initial
    /// replica keeps serving and every request still completes.
    #[test]
    fn empty_mock_outcome_does_not_wedge_engine() {
        let spec = ModelSpec::llama2_13b();
        let mock = MockBackend::new(vec![ScalingOutcome::default()]);
        let mut eng = ServingEngine::new(cluster(4));
        let ms = crate::coordinator::session::ModelSession::for_test(
            spec,
            Box::new(mock),
            burst(10),
        );
        eng.add_model(ms);
        let report = eng.run();
        assert_eq!(report.models[0].metrics.requests.len(), 10);
        assert_eq!(report.models[0].completed, 10);
    }
}
