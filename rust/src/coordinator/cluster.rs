//! Cluster manager: the multi-tenant control plane tying together the model
//! registry, the cluster-wide tiered [`MemoryManager`], and the
//! motivation-study simulations (§2.3, Figs 2–3).
//!
//! Residency lives in the same [`MemoryManager`] type the serving engine
//! owns; the studies here are thin clients of its raw per-node operations
//! (no demotion cascades — each study models exactly one tier transition).

use crate::memory::{Locality, MemoryManager};
use crate::model::{ModelRegistry, ModelSpec};
use crate::sim::time::SimTime;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Multi-tenant cluster state.
pub struct ClusterManager {
    /// Published models.
    pub registry: ModelRegistry,
    /// Cluster-wide tiered residency.
    pub mem: MemoryManager,
}

impl ClusterManager {
    /// A manager over `n_nodes` with uniform per-node tier capacities.
    pub fn new(n_nodes: usize, gpu_capacity: u64, host_capacity: u64) -> Self {
        ClusterManager {
            registry: ModelRegistry::new(),
            mem: MemoryManager::uniform(n_nodes, gpu_capacity, host_capacity),
        }
    }

    /// Publish a model and seed it on every node's SSD (the multi-tenant
    /// platform norm the paper assumes).
    pub fn publish_everywhere(&mut self, spec: ModelSpec) {
        let name = spec.name.clone();
        let bytes = spec.bytes;
        self.registry.publish(spec);
        self.mem.register_model(&name, bytes);
        self.mem.seed_ssd_everywhere(&name);
    }

    /// Loading cases of §2.3 Fig 3. Unknown node ids classify as
    /// [`Locality::Remote`] — a node we do not manage holds no local copy.
    pub fn classify_start(&self, node: usize, model: &str) -> Locality {
        self.mem.locality(node, model)
    }
}

/// Result of the Fig-2 keep-alive study.
pub struct KeepAliveStudy {
    /// Keep-alive durations (seconds): how long each evicted model had gone
    /// unused when LRU reclaimed it — the serverless "keep-alive time" the
    /// paper plots in Fig 2.
    pub residencies: Vec<f64>,
}

/// Fig 2 simulation: `n_models` models on one node whose host memory holds
/// `mem_slots` of them; per-model Poisson requests at `rps_per_model`; LRU
/// eviction on miss. Returns the keep-alive-time distribution.
pub fn keep_alive_study(
    n_models: usize,
    mem_slots: usize,
    rps_per_model: f64,
    duration_s: f64,
    model_bytes: u64,
    rng: &mut Rng,
) -> KeepAliveStudy {
    let mut mem =
        MemoryManager::uniform(1, u64::MAX, model_bytes.saturating_mul(mem_slots as u64));
    let mut residencies = Vec::new();
    let mut last_use: HashMap<String, f64> = HashMap::new();

    // Merge per-model Poisson streams.
    let mut arrivals: Vec<(f64, usize)> = Vec::new();
    for m in 0..n_models {
        let mut t = 0.0;
        loop {
            t += rng.exp(rps_per_model);
            if t >= duration_s {
                break;
            }
            arrivals.push((t, m));
        }
    }
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    for (t, m) in arrivals {
        let name = format!("model{m}");
        let now = SimTime::from_secs(t);
        match mem.locality(0, &name) {
            Locality::HostMem => mem.touch(0, &name, now),
            _ => {
                let evicted = mem.load_host(0, &name, model_bytes, now);
                for e in evicted {
                    if let Some(t0) = last_use.remove(&e) {
                        residencies.push(t - t0);
                    }
                }
            }
        }
        last_use.insert(name, t);
    }
    KeepAliveStudy { residencies }
}

/// Fig 3 load-type proportions from replaying a trace against a keep-alive
/// host-memory cache: (hot, mem, ssd) fractions.
pub fn load_type_study(
    arrivals: &[(f64, usize)],
    mem_slots: usize,
    keep_alive_s: f64,
    gpu_keep_alive_s: f64,
    model_bytes: u64,
) -> (f64, f64, f64) {
    let mut mem = MemoryManager::uniform(
        1,
        model_bytes.saturating_mul(2), // GPU holds ~2 models
        model_bytes.saturating_mul(mem_slots as u64),
    );
    let (mut hot, mut memory, mut ssd) = (0u64, 0u64, 0u64);
    for &(t, m) in arrivals {
        let name = format!("model{m}");
        let now = SimTime::from_secs(t);
        mem.expire_gpu(0, now, SimTime::from_secs(gpu_keep_alive_s));
        mem.expire_host(0, now, SimTime::from_secs(keep_alive_s));
        match mem.locality(0, &name) {
            Locality::Gpu => hot += 1,
            Locality::HostMem => memory += 1,
            _ => ssd += 1,
        }
        mem.load_host(0, &name, model_bytes, now);
        mem.load_gpu(0, &name, model_bytes, now);
        mem.touch(0, &name, now);
    }
    let total = (hot + memory + ssd).max(1) as f64;
    (hot as f64 / total, memory as f64 / total, ssd as f64 / total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_everywhere_seeds_ssd() {
        let mut cm = ClusterManager::new(4, 80_000_000_000, 1_000_000_000_000);
        cm.publish_everywhere(ModelSpec::llama2_7b());
        for n in 0..4 {
            assert_eq!(cm.classify_start(n, "llama2-7b"), Locality::Ssd);
        }
        assert_eq!(cm.registry.len(), 1);
    }

    #[test]
    fn classify_start_unknown_node_is_remote() {
        // Regression: this used to panic on a HashMap index miss.
        let mut cm = ClusterManager::new(2, 80_000_000_000, 1_000_000_000_000);
        cm.publish_everywhere(ModelSpec::llama2_7b());
        assert_eq!(cm.classify_start(7, "llama2-7b"), Locality::Remote);
        assert_eq!(cm.classify_start(usize::MAX, "llama2-7b"), Locality::Remote);
        // Unknown models on known nodes are also just Remote.
        assert_eq!(cm.classify_start(0, "no-such-model"), Locality::Remote);
    }

    #[test]
    fn keep_alive_study_short_residencies() {
        // Paper Fig 2: 12 models, 3 memory slots, 1 req/min/model → the
        // bulk of evictions happen within ~15 s of the model's last use
        // (models churn constantly; the paper reports >95 %, our LRU
        // reconstruction lands lower — see EXPERIMENTS.md — but the shape,
        // "models barely stay resident", holds).
        let mut rng = Rng::new(11);
        let study = keep_alive_study(12, 3, 1.0 / 60.0, 3600.0 * 4.0, 1, &mut rng);
        assert!(study.residencies.len() > 100, "n={}", study.residencies.len());
        let short =
            study.residencies.iter().filter(|&&r| r < 15.0).count() as f64
                / study.residencies.len() as f64;
        assert!(short > 0.5, "short-keep-alive fraction {short}");
        let mut s = crate::util::stats::Samples::new();
        s.extend(&study.residencies);
        assert!(s.p50() < 15.0, "median keep-alive {}", s.p50());
    }

    #[test]
    fn load_type_study_finds_misses() {
        // Round-robin over 12 models with 3 slots: mostly SSD loads.
        let arrivals: Vec<(f64, usize)> =
            (0..600).map(|i| (i as f64 * 5.0, i % 12)).collect();
        let (hot, mem, ssd) = load_type_study(&arrivals, 3, 15.0, 15.0, 1);
        assert!(ssd > 0.5, "ssd fraction {ssd}");
        assert!((hot + mem + ssd - 1.0).abs() < 1e-9);
    }
}
