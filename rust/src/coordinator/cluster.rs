//! Cluster manager: the multi-tenant control plane tying together the model
//! registry, per-node tiered memory, and the motivation-study simulations
//! (§2.3, Figs 2–3).

use crate::memory::{Locality, NodeMemory};
use crate::model::{ModelRegistry, ModelSpec};
use crate::sim::time::SimTime;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Multi-tenant cluster state.
pub struct ClusterManager {
    pub registry: ModelRegistry,
    pub nodes: HashMap<usize, NodeMemory>,
}

impl ClusterManager {
    pub fn new(n_nodes: usize, gpu_capacity: u64, host_capacity: u64) -> Self {
        let nodes =
            (0..n_nodes).map(|n| (n, NodeMemory::new(gpu_capacity, host_capacity))).collect();
        ClusterManager { registry: ModelRegistry::new(), nodes }
    }

    /// Publish a model and seed it on every node's SSD (the multi-tenant
    /// platform norm the paper assumes).
    pub fn publish_everywhere(&mut self, spec: ModelSpec) {
        let name = spec.name.clone();
        self.registry.publish(spec);
        for m in self.nodes.values_mut() {
            m.put_ssd(&name);
        }
    }

    /// Loading cases of §2.3 Fig 3.
    pub fn classify_start(&self, node: usize, model: &str) -> Locality {
        self.nodes[&node].locality(model)
    }
}

/// Result of the Fig-2 keep-alive study.
pub struct KeepAliveStudy {
    /// Keep-alive durations (seconds): how long each evicted model had gone
    /// unused when LRU reclaimed it — the serverless "keep-alive time" the
    /// paper plots in Fig 2.
    pub residencies: Vec<f64>,
}

/// Fig 2 simulation: `n_models` models on one node whose host memory holds
/// `mem_slots` of them; per-model Poisson requests at `rps_per_model`; LRU
/// eviction on miss. Returns the keep-alive-time distribution.
pub fn keep_alive_study(
    n_models: usize,
    mem_slots: usize,
    rps_per_model: f64,
    duration_s: f64,
    model_bytes: u64,
    rng: &mut Rng,
) -> KeepAliveStudy {
    let mut node = NodeMemory::new(u64::MAX, model_bytes.saturating_mul(mem_slots as u64));
    let mut residencies = Vec::new();
    let mut last_use: HashMap<String, f64> = HashMap::new();

    // Merge per-model Poisson streams.
    let mut arrivals: Vec<(f64, usize)> = Vec::new();
    for m in 0..n_models {
        let mut t = 0.0;
        loop {
            t += rng.exp(rps_per_model);
            if t >= duration_s {
                break;
            }
            arrivals.push((t, m));
        }
    }
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    for (t, m) in arrivals {
        let name = format!("model{m}");
        let now = SimTime::from_secs(t);
        match node.locality(&name) {
            Locality::HostMem => node.touch(&name, now),
            _ => {
                let evicted = node.load_host(&name, model_bytes, now);
                for e in evicted {
                    if let Some(t0) = last_use.remove(&e) {
                        residencies.push(t - t0);
                    }
                }
            }
        }
        last_use.insert(name, t);
    }
    KeepAliveStudy { residencies }
}

/// Fig 3 load-type proportions from replaying a trace against a keep-alive
/// host-memory cache: (hot, mem, ssd) fractions.
pub fn load_type_study(
    arrivals: &[(f64, usize)],
    mem_slots: usize,
    keep_alive_s: f64,
    gpu_keep_alive_s: f64,
    model_bytes: u64,
) -> (f64, f64, f64) {
    let mut node = NodeMemory::new(
        model_bytes.saturating_mul(2), // GPU holds ~2 models
        model_bytes.saturating_mul(mem_slots as u64),
    );
    let (mut hot, mut mem, mut ssd) = (0u64, 0u64, 0u64);
    for &(t, m) in arrivals {
        let name = format!("model{m}");
        let now = SimTime::from_secs(t);
        node.expire_gpu(now, SimTime::from_secs(gpu_keep_alive_s));
        node.expire_host(now, SimTime::from_secs(keep_alive_s));
        match node.locality(&name) {
            Locality::Gpu => hot += 1,
            Locality::HostMem => mem += 1,
            _ => ssd += 1,
        }
        node.load_host(&name, model_bytes, now);
        node.load_gpu(&name, model_bytes, now);
        node.touch(&name, now);
    }
    let total = (hot + mem + ssd).max(1) as f64;
    (hot as f64 / total, mem as f64 / total, ssd as f64 / total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_everywhere_seeds_ssd() {
        let mut cm = ClusterManager::new(4, 80_000_000_000, 1_000_000_000_000);
        cm.publish_everywhere(ModelSpec::llama2_7b());
        for n in 0..4 {
            assert_eq!(cm.classify_start(n, "llama2-7b"), Locality::Ssd);
        }
        assert_eq!(cm.registry.len(), 1);
    }

    #[test]
    fn keep_alive_study_short_residencies() {
        // Paper Fig 2: 12 models, 3 memory slots, 1 req/min/model → the
        // bulk of evictions happen within ~15 s of the model's last use
        // (models churn constantly; the paper reports >95 %, our LRU
        // reconstruction lands lower — see EXPERIMENTS.md — but the shape,
        // "models barely stay resident", holds).
        let mut rng = Rng::new(11);
        let study = keep_alive_study(12, 3, 1.0 / 60.0, 3600.0 * 4.0, 1, &mut rng);
        assert!(study.residencies.len() > 100, "n={}", study.residencies.len());
        let short =
            study.residencies.iter().filter(|&&r| r < 15.0).count() as f64
                / study.residencies.len() as f64;
        assert!(short > 0.5, "short-keep-alive fraction {short}");
        let mut s = crate::util::stats::Samples::new();
        s.extend(&study.residencies);
        assert!(s.p50() < 15.0, "median keep-alive {}", s.p50());
    }

    #[test]
    fn load_type_study_finds_misses() {
        // Round-robin over 12 models with 3 slots: mostly SSD loads.
        let arrivals: Vec<(f64, usize)> =
            (0..600).map(|i| (i as f64 * 5.0, i % 12)).collect();
        let (hot, mem, ssd) = load_type_study(&arrivals, 3, 15.0, 15.0, 1);
        assert!(ssd > 0.5, "ssd fraction {ssd}");
        assert!((hot + mem + ssd - 1.0).abs() < 1e-9);
    }
}
