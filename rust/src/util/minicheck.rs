//! Mini property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` seeded cases; on failure it reports the
//! failing seed so the case replays deterministically:
//!
//! ```no_run
//! use lambda_scale::util::minicheck::check;
//! check("rng below is bounded", 200, |rng| {
//!     let n = rng.range(1, 1000);
//!     let x = rng.below(n);
//!     assert!(x < n, "x={x} n={n}");
//! });
//! ```

use super::rng::Rng;

/// Run `prop` over `cases` deterministic seeds. Panics (with the seed) on the
/// first failing case. Set `MINICHECK_SEED` to replay one specific seed.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    if let Ok(s) = std::env::var("MINICHECK_SEED") {
        let seed: u64 = s.parse().expect("MINICHECK_SEED must be a u64");
        let mut rng = Rng::new(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property `{name}` failed on case {case} (replay with MINICHECK_SEED={seed}): {msg}"
            );
        }
    }
}

/// Draw a vector of length in [min_len, max_len] with elements from `gen`.
pub fn vec_of<T>(rng: &mut Rng, min_len: usize, max_len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let len = rng.range(min_len as u64, max_len as u64) as usize;
    (0..len).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 50, |rng| {
            let x = rng.below(10);
            assert!(x < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn failing_property_reports_seed() {
        // Silence the panic backtrace noise from catch_unwind.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check("failing", 50, |rng| {
                assert!(rng.below(10) < 5, "too big");
            });
        });
        std::panic::set_hook(prev);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn vec_of_respects_bounds() {
        check("vec_of bounds", 50, |rng| {
            let v = vec_of(rng, 2, 9, |r| r.below(100));
            assert!(v.len() >= 2 && v.len() <= 9);
        });
    }
}
