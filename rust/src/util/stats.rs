//! Summary statistics: percentiles, CDFs, histograms, online mean/variance.
//!
//! Used by the metrics layer (TTFT/TPS distributions) and the figure
//! generators (every CDF figure in the paper flows through [`Cdf`]).

/// A growable sample set with percentile queries (exact, sort-on-demand).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    data: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take ownership of a full sample vector and sort it **once**, so
    /// every subsequent percentile query is a pure lookup. Prefer this
    /// over `push`-loops when the values already live in a `Vec`: the
    /// sort-on-demand path re-sorts after any mutation, and bulk
    /// construction is the common case in the metrics layer.
    pub fn from_vec(mut data: Vec<f64>) -> Self {
        data.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Samples { data, sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.data.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.data.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile with linear interpolation; `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.data.is_empty(), "percentile of empty sample set");
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        let n = self.data.len();
        if n == 1 {
            return self.data[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.data[lo] * (1.0 - frac) + self.data[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p90(&mut self) -> f64 {
        self.percentile(90.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.data[0]
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        *self.data.last().unwrap()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return f64::NAN;
        }
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Empirical CDF sampled at `points` evenly spaced quantiles.
    pub fn cdf(&mut self, points: usize) -> Cdf {
        self.ensure_sorted();
        let n = self.data.len();
        assert!(n > 0 && points >= 2);
        let mut xs = Vec::with_capacity(points);
        let mut ps = Vec::with_capacity(points);
        for i in 0..points {
            let q = i as f64 / (points - 1) as f64;
            let idx = ((n - 1) as f64 * q).round() as usize;
            xs.push(self.data[idx]);
            ps.push((idx + 1) as f64 / n as f64);
        }
        Cdf { xs, ps }
    }

    pub fn values(&mut self) -> &[f64] {
        self.ensure_sorted();
        &self.data
    }
}

/// An empirical CDF: (value, cumulative probability) pairs.
#[derive(Clone, Debug)]
pub struct Cdf {
    pub xs: Vec<f64>,
    pub ps: Vec<f64>,
}

impl Cdf {
    /// Fraction of mass at or below `x`.
    pub fn at(&self, x: f64) -> f64 {
        match self.xs.iter().rposition(|&v| v <= x) {
            Some(i) => self.ps[i],
            None => 0.0,
        }
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to end bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Histogram { lo, hi, bins: vec![0; n_bins], count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x < self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.bins[idx.min(n - 1)] += 1;
        self.count += 1;
    }

    /// Fraction of samples in each bin.
    pub fn normalized(&self) -> Vec<f64> {
        if self.count == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&b| b as f64 / self.count as f64).collect()
    }

    pub fn bin_edges(&self) -> Vec<f64> {
        let n = self.bins.len();
        (0..=n).map(|i| self.lo + (self.hi - self.lo) * i as f64 / n as f64).collect()
    }
}

/// Welford online mean/variance — allocation-free hot-loop statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Samples::new();
        s.extend(&[0.0, 10.0]);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-12);
        assert!((s.p90() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn unsorted_input_ok() {
        let mut s = Samples::new();
        s.extend(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.p50(), 3.0);
        s.push(0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn percentiles_monotone_on_unsorted_input() {
        // Micro-assert: p0 ≤ p50 ≤ p100 must hold no matter how scrambled
        // the input order is — a regression here means ensure_sorted (or a
        // from_vec construction) failed to actually sort.
        let scrambled = vec![9.0, 0.5, 7.0, 3.0, 8.0, 1.0, 6.5, 2.0, 4.0, 5.0];
        let mut pushed = Samples::new();
        pushed.extend(&scrambled);
        let mut bulk = Samples::from_vec(scrambled);
        for s in [&mut pushed, &mut bulk] {
            let (p0, p50, p100) = (s.percentile(0.0), s.p50(), s.percentile(100.0));
            assert!(p0 <= p50 && p50 <= p100, "p0={p0} p50={p50} p100={p100}");
            assert_eq!(p0, 0.5);
            assert_eq!(p100, 9.0);
        }
        assert_eq!(pushed.p50(), bulk.p50());
    }

    #[test]
    fn cdf_monotone() {
        let mut s = Samples::new();
        for i in 0..100 {
            s.push((i * 7 % 100) as f64);
        }
        let cdf = s.cdf(20);
        for w in cdf.xs.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for w in cdf.ps.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!((cdf.ps.last().unwrap() - 1.0).abs() < 1e-12);
        assert!(cdf.at(49.0) > 0.4 && cdf.at(49.0) < 0.6);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.bins.iter().all(|&b| b == 1));
        h.add(-5.0);
        h.add(99.0);
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        let norm = h.normalized();
        assert!((norm.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::default();
        for &x in &xs {
            o.add(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        assert!((o.variance() - 32.0 / 7.0).abs() < 1e-12);
    }
}
