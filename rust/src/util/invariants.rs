//! Runtime-checkable conservation invariants.
//!
//! The simulator's correctness rests on a handful of conservation laws —
//! KV-pool block counts, prefix-table refcounts, fabric flow accounting,
//! memory-manager byte accounting. Historically each was a scattered
//! `debug_assert!`, which meant release-mode eval runs (the only runs big
//! enough to hit rare interleavings) never checked them at all.
//!
//! This module centralizes the switch: [`invariant!`](crate::invariant) and
//! [`invariant_eq!`](crate::invariant_eq) behave exactly like
//! `debug_assert!` / `debug_assert_eq!` in debug builds, are compiled to a
//! single relaxed atomic load in release builds, and can be enabled at
//! runtime in release mode with the `--paranoid` CLI flag (or
//! [`set_paranoid`]) so long eval runs can opt into full checking.

use std::sync::atomic::{AtomicBool, Ordering};

/// Release-mode opt-in: when set, [`paranoid`] returns `true` and every
/// `invariant!` site checks its condition even in optimized builds.
static PARANOID: AtomicBool = AtomicBool::new(false);

/// Enable (or disable) release-mode invariant checking. Wired to the
/// `--paranoid` global CLI flag; safe to call from tests.
pub fn set_paranoid(on: bool) {
    PARANOID.store(on, Ordering::Relaxed);
}

/// Whether invariant conditions are evaluated: always in debug builds,
/// opt-in via [`set_paranoid`] in release builds.
pub fn paranoid() -> bool {
    cfg!(debug_assertions) || PARANOID.load(Ordering::Relaxed)
}

/// A conservation check: `assert!` that is always on in debug builds and
/// opt-in (via `--paranoid` / [`set_paranoid`]) in release builds.
///
/// The condition is not evaluated unless checking is enabled, so the
/// guarded expression may be arbitrarily expensive (full-table scans).
#[macro_export]
macro_rules! invariant {
    ($($arg:tt)*) => {
        if $crate::util::invariants::paranoid() {
            assert!($($arg)*);
        }
    };
}

/// Equality form of [`invariant!`](crate::invariant): `assert_eq!` that is
/// always on in debug builds and opt-in in release builds.
#[macro_export]
macro_rules! invariant_eq {
    ($($arg:tt)*) => {
        if $crate::util::invariants::paranoid() {
            assert_eq!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paranoid_toggles_release_checking() {
        // In test (debug) builds `paranoid()` is always true; the runtime
        // toggle must at minimum round-trip its flag.
        set_paranoid(true);
        assert!(paranoid());
        set_paranoid(false);
        assert!(cfg!(debug_assertions) || !paranoid());
    }

    #[test]
    fn invariant_passes_on_true_condition() {
        let two = std::hint::black_box(2);
        invariant!(two == 2, "arithmetic holds");
        invariant_eq!(two, 2, "equality holds");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "conservation broken")]
    fn invariant_fires_in_debug() {
        let broken = std::hint::black_box(false);
        invariant!(broken, "conservation broken");
    }
}
