//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! All randomness in the simulator, workload generators and property tests
//! flows through this type so every experiment is reproducible from a seed.

/// xoshiro256** generator (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-entity RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire's nearly-divisionless bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson inter-arrivals.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with underlying mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang (k >= 1 fast path,
    /// boost for k < 1).
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * theta;
            }
        }
    }

    /// Poisson-distributed count with mean `lambda` (inversion for small,
    /// normal approximation for large lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        let x = lambda + lambda.sqrt() * self.normal();
        if x < 0.0 {
            0
        } else {
            x.round() as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(13);
        for lam in [0.5, 5.0, 80.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.08, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn gamma_mean_close() {
        let mut r = Rng::new(17);
        let n = 30_000;
        let (k, th) = (0.6, 2.0);
        let mean: f64 = (0..n).map(|_| r.gamma(k, th)).sum::<f64>() / n as f64;
        assert!((mean - k * th).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03 && (var - 1.0).abs() < 0.05);
    }
}
