//! Minimal JSON: a full parser (for `artifacts/manifest.json`, `golden.json`
//! and trace files) and a writer (for experiment outputs).
//!
//! Supports the complete JSON grammar minus exotic number forms; numbers are
//! held as f64 (adequate for every manifest field we read — offsets stay
//! under 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that panics with a useful message (manifest
    /// contract violations are programming errors, not runtime conditions).
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key).unwrap_or_else(|| panic!("missing JSON key `{key}` in {self:.60?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn u(&self, key: &str) -> u64 {
        self.expect(key).as_u64().unwrap_or_else(|| panic!("JSON key `{key}` not a number"))
    }

    pub fn us(&self, key: &str) -> usize {
        self.u(key) as usize
    }

    pub fn f(&self, key: &str) -> f64 {
        self.expect(key).as_f64().unwrap_or_else(|| panic!("JSON key `{key}` not a number"))
    }

    pub fn s(&self, key: &str) -> &str {
        self.expect(key).as_str().unwrap_or_else(|| panic!("JSON key `{key}` not a string"))
    }

    pub fn arr(&self, key: &str) -> &[Json] {
        self.expect(key).as_arr().unwrap_or_else(|| panic!("JSON key `{key}` not an array"))
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders for emitting results.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.arr("a")[2].s("b"), "x");
        assert_eq!(j.expect("c"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"d_model":256,"eps":1e-05},"list":[1,2,3],"name":"λScale \"x\""}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""λScale""#).unwrap(), Json::Str("λScale".into()));
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 42, "s": "hi", "b": false}"#).unwrap();
        assert_eq!(j.u("n"), 42);
        assert_eq!(j.s("s"), "hi");
        assert_eq!(j.expect("b").as_bool(), Some(false));
        assert!(j.get("missing").is_none());
    }
}
