//! Criterion-style micro/macro benchmark harness (criterion is unavailable
//! offline). Each `benches/*.rs` target sets `harness = false` and drives
//! this runner; `cargo bench` therefore works end-to-end.
//!
//! Features: warmup, adaptive iteration count targeting a fixed measurement
//! window, mean/p50/p99 reporting, and a plain-text table printer used by
//! the figure regeneration benches.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<48} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark `f`, autoscaling iterations to ~`budget` of wall time.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(1));
    let target_iters = (budget.as_nanos() / once.as_nanos()).clamp(5, 100_000) as u64;

    let mut samples: Vec<Duration> = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let result = BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean: total / target_iters as u32,
        p50: samples[samples.len() / 2],
        p99: samples[(samples.len() as f64 * 0.99) as usize % samples.len()],
    };
    result.report();
    result
}

/// Quick-and-dirty single measurement for long-running figure generators.
pub fn measure<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t = Instant::now();
    let out = f();
    println!("{:<48} completed in {}", name, fmt_dur(t.elapsed()));
    out
}

/// Plain-text table printer for figure/table regeneration output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p99);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with("s"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
