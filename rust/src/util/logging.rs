//! Leveled stderr logger with a process-global level.
//!
//! `LAMBDA_SCALE_LOG={error|warn|info|debug|trace}` (default `info`) or
//! programmatic [`set_level`]. Zero cost below the active level beyond one
//! atomic load.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("LAMBDA_SCALE_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == 255 { init_from_env() } else { cur };
    (l as u8) <= cur
}

/// Process start, for relative timestamps.
pub fn epoch() -> Instant {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = epoch().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {module}] {args}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
