//! Zero-dependency substrates.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so everything a serving framework normally pulls from crates.io
//! (serde, rand, criterion, proptest, a logger) is implemented here from
//! scratch, small and auditable.
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod bench;
pub mod invariants;
pub mod json;
pub mod logging;
pub mod minicheck;
pub mod rng;
pub mod stats;

pub use rng::Rng;
