"""L2 correctness: block partitioning, KV-cache decode, pallas/ref equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def test_param_specs_cover_all_layers():
    seen = set()
    for b in range(CFG.n_blocks):
        for name, _ in M.block_param_specs(CFG, b):
            assert name not in seen, f"duplicate tensor {name}"
            seen.add(name)
    for layer in range(CFG.n_layers):
        assert f"layer{layer}.wq" in seen
    assert "tok_embed" in seen and "lm_head" in seen and "final_norm" in seen


def test_layers_per_block_partition():
    for nb in range(1, 5):
        cfg = M.ModelConfig(n_layers=7, n_blocks=nb)
        lpb = cfg.layers_per_block
        assert sum(lpb) == 7 and len(lpb) == nb
        assert max(lpb) - min(lpb) <= 1
        ranges = [cfg.block_layer_range(b) for b in range(nb)]
        assert ranges[0][0] == 0 and ranges[-1][1] == 7
        for (lo1, hi1), (lo2, _) in zip(ranges, ranges[1:]):
            assert hi1 == lo2


def test_param_count_matches_init(params):
    n = sum(int(np.prod(p.shape)) for blk in params for p in blk)
    assert n == CFG.param_count()


def test_prefill_pallas_matches_ref(params):
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, CFG.prefill_len), 0, CFG.vocab)
    lo_ref, _ = M.forward(CFG, params, prompt, M.init_caches(CFG, 2), jnp.int32(0), False)
    lo_pl, _ = M.forward(CFG, params, prompt, M.init_caches(CFG, 2), jnp.int32(0), True)
    np.testing.assert_allclose(lo_ref, lo_pl, rtol=2e-4, atol=2e-4)


def test_decode_pallas_matches_ref(params):
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, CFG.vocab)
    g_ref = M.generate(CFG, params, prompt, 6, use_pallas=False)
    g_pl = M.generate(CFG, params, prompt, 6, use_pallas=True)
    assert g_ref.tolist() == g_pl.tolist()


def test_kv_decode_equals_full_context(params):
    """Incremental decode with KV cache == re-running the full prefix each step."""
    batch = 1
    p_len = 6
    prompt = jax.random.randint(jax.random.PRNGKey(3), (batch, p_len), 0, CFG.vocab)
    # Incremental: prefill then decode one token.
    caches = M.init_caches(CFG, batch)
    logits, caches = M.forward(CFG, params, prompt, caches, jnp.int32(0), False)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    logits_inc, _ = M.forward(CFG, params, tok[:, None], caches, jnp.int32(p_len), False)
    # Full-context: rerun prefill over prompt+tok.
    full = jnp.concatenate([prompt, tok[:, None]], axis=1)
    logits_full, _ = M.forward(CFG, params, full, M.init_caches(CFG, batch), jnp.int32(0), False)
    np.testing.assert_allclose(
        logits_inc[:, 0, :], logits_full[:, -1, :], rtol=2e-4, atol=2e-4)


def test_block_chain_equals_forward(params):
    """Chaining block_forward by hand == forward() (the Rust runtime contract)."""
    batch = 2
    prompt = jax.random.randint(jax.random.PRNGKey(4), (batch, CFG.prefill_len), 0, CFG.vocab)
    caches = M.init_caches(CFG, batch)
    x = prompt
    for b in range(CFG.n_blocks):
        kc, vc = caches[b]
        x, _, _ = M.block_forward(CFG, b, params[b], x, kc, vc, jnp.int32(0), False)
    expected, _ = M.forward(CFG, params, prompt, M.init_caches(CFG, batch), jnp.int32(0), False)
    np.testing.assert_allclose(x, expected, rtol=1e-5, atol=1e-5)


def test_batch_independence(params):
    """Each batch row decodes independently (no cross-batch leakage)."""
    p = jax.random.randint(jax.random.PRNGKey(5), (2, 4), 0, CFG.vocab)
    both = M.generate(CFG, params, p, 4, use_pallas=False)
    row0 = M.generate(CFG, params, p[:1], 4, use_pallas=False)
    row1 = M.generate(CFG, params, p[1:], 4, use_pallas=False)
    assert both[0].tolist() == row0[0].tolist()
    assert both[1].tolist() == row1[0].tolist()


def test_generate_deterministic(params):
    p = jax.random.randint(jax.random.PRNGKey(6), (1, 4), 0, CFG.vocab)
    a = M.generate(CFG, params, p, 5, use_pallas=False)
    b = M.generate(CFG, params, p, 5, use_pallas=False)
    assert a.tolist() == b.tolist()


def test_tokens_in_vocab_range(params):
    p = jax.random.randint(jax.random.PRNGKey(7), (2, 4), 0, CFG.vocab)
    toks = np.asarray(M.generate(CFG, params, p, 6, use_pallas=False))
    assert (toks >= 0).all() and (toks < CFG.vocab).all()


def test_rope_positions_matter(params):
    """Same token at different positions must produce different logits."""
    tok = jnp.full((1, 1), 3, jnp.int32)
    caches = M.init_caches(CFG, 1)
    l0, _ = M.forward(CFG, params, tok, caches, jnp.int32(0), False)
    l5, _ = M.forward(CFG, params, tok, M.init_caches(CFG, 1), jnp.int32(5), False)
    assert not np.allclose(np.asarray(l0), np.asarray(l5), atol=1e-5)
