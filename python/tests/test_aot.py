"""AOT pipeline: manifest contract, packed weights round-trip, HLO validity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, "tiny", batches=[1, 2], seed=0,
                         golden_tokens=4, golden_batch=1)
    return out, manifest


def test_manifest_structure(built):
    out, manifest = built
    cfg = M.PRESETS["tiny"]
    assert manifest["config"]["n_blocks"] == cfg.n_blocks
    assert manifest["config"]["param_count"] == cfg.param_count()
    assert len(manifest["blocks"]) == cfg.n_blocks
    # 2 phases x 2 batches per block
    assert len(manifest["artifacts"]) == cfg.n_blocks * 4
    for art in manifest["artifacts"]:
        assert os.path.exists(os.path.join(out, art["path"]))
        assert art["phase"] in ("prefill", "decode")
        assert art["seq"] == (1 if art["phase"] == "decode" else cfg.prefill_len)


def test_packed_weights_roundtrip(built):
    """Offsets/sizes in the manifest must reconstruct the original tensors."""
    out, manifest = built
    cfg = M.PRESETS["tiny"]
    params = M.init_params(cfg, seed=0)
    for blk in manifest["blocks"]:
        blob = open(os.path.join(out, blk["weights_file"]), "rb").read()
        assert len(blob) == blk["weights_bytes"]
        for spec, expected in zip(blk["tensors"], params[blk["index"]]):
            raw = blob[spec["offset_bytes"]: spec["offset_bytes"] + spec["size_bytes"]]
            arr = np.frombuffer(raw, dtype="<f4").reshape(spec["shape"])
            np.testing.assert_array_equal(arr, np.asarray(expected))


def test_tensor_packing_contiguous(built):
    """λScale tensor packing: no gaps, no overlaps, in declared order."""
    _, manifest = built
    for blk in manifest["blocks"]:
        cursor = 0
        for spec in blk["tensors"]:
            assert spec["offset_bytes"] == cursor
            assert spec["size_bytes"] == 4 * int(np.prod(spec["shape"]))
            cursor += spec["size_bytes"]
        assert cursor == blk["weights_bytes"]


def test_hlo_text_is_parseable_entry(built):
    out, manifest = built
    for art in manifest["artifacts"]:
        text = open(os.path.join(out, art["path"])).read()
        assert "ENTRY" in text and "HloModule" in text
        # return_tuple=True => root is a 3-tuple (out, k_cache, v_cache)
        assert "tuple(" in text.replace(" ", "") or "tuple " in text


def test_golden_matches_regenerated(built):
    out, manifest = built
    cfg = M.PRESETS["tiny"]
    golden = json.load(open(os.path.join(out, "golden.json")))
    params = M.init_params(cfg, seed=0)
    prompt = jnp.asarray(golden["prompt"], jnp.int32)
    toks = M.generate(cfg, params, prompt, golden["n_tokens"], use_pallas=True)
    assert toks.tolist() == golden["tokens"]


def test_artifact_param_order_matches_specs(built):
    _, manifest = built
    cfg = M.PRESETS["tiny"]
    for art in manifest["artifacts"]:
        assert art["n_weight_params"] == len(M.block_param_specs(cfg, art["block"]))
