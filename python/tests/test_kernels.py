"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention_decode, matmul, rmsnorm, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 1, (k, n))
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 1), (128, 128, 128), (7, 13, 17), (256, 32, 64)])
def test_matmul_shapes(shape):
    m, k, n = shape
    x = rand(0, (m, k))
    y = rand(1, (k, n))
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tiles", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
def test_matmul_tile_invariance(tiles):
    """Result must not depend on the tile decomposition."""
    bm, bn, bk = tiles
    x = rand(2, (64, 64))
    y = rand(3, (64, 64))
    base = ref.matmul_ref(x, y)
    np.testing.assert_allclose(matmul(x, y, bm=bm, bn=bn, bk=bk), base, rtol=1e-4, atol=1e-4)


def test_matmul_inner_dim_mismatch_raises():
    with pytest.raises(AssertionError):
        matmul(rand(0, (4, 5)), rand(1, (6, 4)))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    r=st.integers(1, 64),
    d=st.integers(1, 256),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(r, d, seed):
    x = rand(seed, (r, d))
    w = rand(seed + 1, (d,))
    np.testing.assert_allclose(rmsnorm(x, w), ref.rmsnorm_ref(x, w), rtol=1e-4, atol=1e-4)


def test_rmsnorm_unit_weight_normalizes():
    x = rand(7, (4, 64)) * 10.0
    out = np.asarray(rmsnorm(x, jnp.ones(64)))
    rms = np.sqrt((out**2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rmsnorm_scale_equivariance():
    """rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps)."""
    x = rand(8, (4, 32))
    w = rand(9, (32,))
    a = np.asarray(rmsnorm(x, w))
    b = np.asarray(rmsnorm(x * 1000.0, w))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# attention decode
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([8, 16]),
    data=st.data(),
)
def test_attention_decode_matches_ref(b, h, s_blocks, d, block_k, data):
    s = block_k * s_blocks
    pos = data.draw(st.integers(0, s - 1))
    q = rand(b * 7 + 1, (b, h, 1, d))
    k = rand(h * 11 + 2, (b, h, s, d))
    v = rand(d * 13 + 3, (b, h, s, d))
    out = attention_decode(q, k, v, jnp.int32(pos), block_k=block_k)
    exp = ref.attention_decode_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-5)


def test_attention_pos0_attends_only_first():
    """With pos=0 the output is exactly v[..., 0, :]."""
    b, h, s, d = 1, 2, 16, 8
    q = rand(0, (b, h, 1, d))
    k = rand(1, (b, h, s, d))
    v = rand(2, (b, h, s, d))
    out = attention_decode(q, k, v, jnp.int32(0), block_k=8)
    np.testing.assert_allclose(out[:, :, 0, :], v[:, :, 0, :], rtol=1e-5, atol=1e-6)


def test_attention_masks_future_positions():
    """Garbage beyond pos must not change the result."""
    b, h, s, d = 2, 2, 32, 16
    q = rand(3, (b, h, 1, d))
    k = rand(4, (b, h, s, d))
    v = rand(5, (b, h, s, d))
    pos = 10
    out1 = attention_decode(q, k, v, jnp.int32(pos), block_k=8)
    k2 = k.at[:, :, pos + 1 :, :].set(1e6)
    v2 = v.at[:, :, pos + 1 :, :].set(-1e6)
    out2 = attention_decode(q, k2, v2, jnp.int32(pos), block_k=8)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)


def test_attention_softmax_convexity():
    """Output lies in the convex hull of the visible v rows (per coordinate bounds)."""
    b, h, s, d = 1, 1, 16, 8
    q = rand(6, (b, h, 1, d))
    k = rand(7, (b, h, s, d))
    v = rand(8, (b, h, s, d))
    pos = 7
    out = np.asarray(attention_decode(q, k, v, jnp.int32(pos), block_k=8))[0, 0, 0]
    vis = np.asarray(v)[0, 0, : pos + 1]
    assert (out <= vis.max(axis=0) + 1e-5).all()
    assert (out >= vis.min(axis=0) - 1e-5).all()


def test_attention_block_k_invariance():
    b, h, s, d = 2, 3, 32, 16
    q = rand(9, (b, h, 1, d))
    k = rand(10, (b, h, s, d))
    v = rand(11, (b, h, s, d))
    outs = [np.asarray(attention_decode(q, k, v, jnp.int32(17), block_k=bk))
            for bk in (8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-6)
