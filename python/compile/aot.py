"""AOT compile path: lower every model block to HLO text + pack weights.

Emits (under --out-dir, default ../artifacts):
  hlo/block{i}_{phase}_b{batch}.hlo.txt   — one HLO module per (block, phase, batch)
  weights/block{i}.bin                    — λScale "tensor packing": every tensor of a
                                            block concatenated into ONE contiguous
                                            little-endian f32 buffer (bulk-transfer unit)
  manifest.json                           — shapes/offsets/param-order contract for Rust
  golden.json                             — greedy-decode golden tokens for integration tests

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns ids.

Python runs once at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block(cfg: M.ModelConfig, block: int, batch: int, seq: int) -> str:
    """Lower one block forward to HLO text for a fixed (batch, seq)."""
    fn = M.make_block_fn(cfg, block, use_pallas=True)
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32)
             for _, shape in M.block_param_specs(cfg, block)]
    if block == 0:
        x_spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    else:
        x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)
    lo, hi = cfg.block_layer_range(block)
    cache_shape = (hi - lo, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    kc = jax.ShapeDtypeStruct(cache_shape, jnp.float32)
    vc = jax.ShapeDtypeStruct(cache_shape, jnp.float32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(fn).lower(*specs, x_spec, kc, vc, pos)
    return to_hlo_text(lowered)


def pack_weights(cfg: M.ModelConfig, block: int, params) -> tuple[bytes, list]:
    """Tensor packing: concatenate all tensors of a block, record offsets."""
    buf = bytearray()
    tensors = []
    for (name, shape), arr in zip(M.block_param_specs(cfg, block), params):
        raw = np.asarray(arr, dtype="<f4").tobytes()
        tensors.append({
            "name": name,
            "shape": list(shape),
            "offset_bytes": len(buf),
            "size_bytes": len(raw),
        })
        buf.extend(raw)
    return bytes(buf), tensors


def build(out_dir: str, preset: str, batches: list[int], seed: int,
          golden_tokens: int, golden_batch: int) -> dict:
    cfg = M.PRESETS[preset]
    os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    params = M.init_params(cfg, seed)
    manifest = {
        "preset": preset,
        "seed": seed,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq, "n_blocks": cfg.n_blocks,
            "prefill_len": cfg.prefill_len, "norm_eps": cfg.norm_eps,
            "rope_theta": cfg.rope_theta,
            "param_count": cfg.param_count(),
        },
        "blocks": [],
        "artifacts": [],
    }

    for b in range(cfg.n_blocks):
        blob, tensors = pack_weights(cfg, b, params[b])
        wpath = f"weights/block{b}.bin"
        with open(os.path.join(out_dir, wpath), "wb") as f:
            f.write(blob)
        lo, hi = cfg.block_layer_range(b)
        manifest["blocks"].append({
            "index": b,
            "layer_start": lo,
            "layer_end": hi,
            "weights_file": wpath,
            "weights_bytes": len(blob),
            "cache_shape": [hi - lo, 0, cfg.max_seq, cfg.n_heads, cfg.head_dim],
            "tensors": tensors,
        })

    for b in range(cfg.n_blocks):
        for phase, seq in (("prefill", cfg.prefill_len), ("decode", 1)):
            for batch in batches:
                t0 = time.time()
                hlo = lower_block(cfg, b, batch, seq)
                path = f"hlo/block{b}_{phase}_b{batch}.hlo.txt"
                with open(os.path.join(out_dir, path), "w") as f:
                    f.write(hlo)
                manifest["artifacts"].append({
                    "path": path, "block": b, "phase": phase,
                    "batch": batch, "seq": seq,
                    "n_weight_params": len(M.block_param_specs(cfg, b)),
                    "x_dtype": "i32" if b == 0 else "f32",
                    "out_kind": "logits" if b == cfg.n_blocks - 1 else "hidden",
                })
                print(f"lowered {path} ({len(hlo)//1024} KiB, {time.time()-t0:.1f}s)",
                      flush=True)

    # Golden: greedy generation through the same pallas path the HLO encodes.
    key = jax.random.PRNGKey(seed + 1)
    prompt = jax.random.randint(key, (golden_batch, cfg.prefill_len), 0, cfg.vocab,
                                dtype=jnp.int32)
    t0 = time.time()
    toks = M.generate(cfg, params, prompt, golden_tokens, use_pallas=True)
    golden = {
        "preset": preset,
        "prompt": np.asarray(prompt).tolist(),
        "tokens": np.asarray(toks).tolist(),
        "n_tokens": golden_tokens,
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"golden generated in {time.time()-t0:.1f}s: {golden['tokens']}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--batches", default="1,8",
                    help="comma-separated batch sizes to specialize HLO for")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--golden-tokens", type=int, default=8)
    ap.add_argument("--golden-batch", type=int, default=1)
    args = ap.parse_args()
    batches = [int(x) for x in args.batches.split(",")]
    t0 = time.time()
    build(args.out_dir, args.preset, batches, args.seed,
          args.golden_tokens, args.golden_batch)
    print(f"artifacts complete in {time.time()-t0:.1f}s → {args.out_dir}")


if __name__ == "__main__":
    main()
