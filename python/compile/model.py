"""L2: block-partitioned Llama-architecture transformer in JAX.

This mirrors λScale's *model block* abstraction (§4.2): the model is
partitioned into `n_blocks` contiguous groups of layers. Each block has its
own forward function (embedding folded into block 0, final norm + LM head
into the last block), so λScale's Rust coordinator can run a *distributed
execution pipeline* by chaining per-block HLO executables across nodes while
the remaining blocks are still in flight on the multicast.

Decode-path hot spots call the L1 Pallas kernels (attention_decode, matmul,
rmsnorm); prefill attention uses the jnp reference (it runs once per request
and is not the paper's hot spot).

Everything here is build-time only: aot.py lowers each block function to HLO
text; Python never touches the request path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention_decode, matmul, rmsnorm
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the tiny Llama-style model (MHA, RoPE, SwiGLU)."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 8
    n_heads: int = 8
    d_ff: int = 512
    max_seq: int = 128
    n_blocks: int = 4
    prefill_len: int = 16
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def layers_per_block(self) -> List[int]:
        """Number of layers in each block (as even as possible)."""
        base = self.n_layers // self.n_blocks
        rem = self.n_layers % self.n_blocks
        return [base + (1 if i < rem else 0) for i in range(self.n_blocks)]

    def block_layer_range(self, block: int) -> Tuple[int, int]:
        lpb = self.layers_per_block
        start = sum(lpb[:block])
        return start, start + lpb[block]

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + self.n_layers * per_layer + d + d * v


PRESETS: Dict[str, ModelConfig] = {
    # Fast unit-test config.
    "tiny": ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64,
                        max_seq=32, n_blocks=2, prefill_len=8),
    # Default artifact config (~5.5M params): big enough to be a real model,
    # small enough for Pallas-interpret HLO to compile and run quickly on CPU.
    "small": ModelConfig(),
    # Larger config for throughput experiments (~21M params).
    "base": ModelConfig(vocab=1024, d_model=384, n_layers=12, n_heads=12,
                        d_ff=1024, max_seq=256, n_blocks=4, prefill_len=32),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _layer_param_names() -> List[str]:
    return ["attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w1", "w2", "w3"]


def block_param_specs(cfg: ModelConfig, block: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list for one block — the AOT/manifest contract.

    The order here defines both the packed .bin layout (λScale tensor packing:
    every tensor of a block lives in one contiguous buffer) and the HLO
    parameter order.
    """
    d, f = cfg.d_model, cfg.d_ff
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    if block == 0:
        specs.append(("tok_embed", (cfg.vocab, d)))
    lo, hi = cfg.block_layer_range(block)
    for layer in range(lo, hi):
        shapes = {
            "attn_norm": (d,), "wq": (d, d), "wk": (d, d), "wv": (d, d),
            "wo": (d, d), "ffn_norm": (d,), "w1": (d, f), "w2": (f, d), "w3": (d, f),
        }
        for name in _layer_param_names():
            specs.append((f"layer{layer}.{name}", shapes[name]))
    if block == cfg.n_blocks - 1:
        specs.append(("final_norm", (d,)))
        specs.append(("lm_head", (d, cfg.vocab)))
    return specs


def init_block_params(cfg: ModelConfig, block: int, seed: int = 0) -> List[jnp.ndarray]:
    """Deterministic init for one block, in block_param_specs order."""
    params = []
    for i, (name, shape) in enumerate(block_param_specs(cfg, block)):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), hash((block, i)) % (2**31))
        if name.endswith("norm") or name.endswith("attn_norm") or name.endswith("ffn_norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            scale = 0.02 if "embed" in name or "head" in name else 1.0 / (shape[0] ** 0.5)
            params.append(scale * jax.random.normal(key, shape, jnp.float32))
    return params


def init_params(cfg: ModelConfig, seed: int = 0) -> List[List[jnp.ndarray]]:
    return [init_block_params(cfg, b, seed) for b in range(cfg.n_blocks)]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [B, S, H, D], positions: [S] (absolute)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float, use_pallas: bool) -> jnp.ndarray:
    b, s, d = x.shape
    if use_pallas:
        return rmsnorm(x.reshape(b * s, d), w, eps=eps).reshape(b, s, d)
    return kref.rmsnorm_ref(x, w, eps)


def _mm(x: jnp.ndarray, w: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    """[B, S, d] @ [d, n] via the Pallas tiled matmul (or jnp fallback)."""
    b, s, d = x.shape
    if use_pallas:
        return matmul(x.reshape(b * s, d), w).reshape(b, s, w.shape[1])
    return jnp.matmul(x, w)


def _attention(
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, S, d] (normed)
    wq, wk, wv, wo,
    k_cache: jnp.ndarray,  # [B, max_seq, H, Dh]
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,  # scalar i32: first absolute position of this chunk
    use_pallas: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = _mm(x, wq, use_pallas).reshape(b, s, h, dh)
    k = _mm(x, wk, use_pallas).reshape(b, s, h, dh)
    v = _mm(x, wv, use_pallas).reshape(b, s, h, dh)

    positions = pos + jnp.arange(s)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))

    if s == 1:
        # Decode: L1 Pallas flash-decode kernel over the cache buffer.
        qt = q.transpose(0, 2, 1, 3)  # [B, H, 1, Dh]
        kt = k_cache.transpose(0, 2, 1, 3)  # [B, H, max_seq, Dh]
        vt = v_cache.transpose(0, 2, 1, 3)
        if use_pallas:
            o = attention_decode(qt, kt, vt, pos)
        else:
            o = kref.attention_decode_ref(qt, kt, vt, pos)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, d)
    else:
        # Prefill: causal attention over the fresh chunk (pos == 0 by contract).
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = kref.attention_prefill_ref(qt, kt, vt)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)

    return _mm(o, wo, use_pallas), k_cache, v_cache


def _mlp(x, w1, w2, w3, use_pallas: bool) -> jnp.ndarray:
    a = _mm(x, w1, use_pallas)
    g = a * (1.0 / (1.0 + jnp.exp(-a)))
    u = _mm(x, w3, use_pallas)
    return _mm(g * u, w2, use_pallas)


def block_forward(
    cfg: ModelConfig,
    block: int,
    params: List[jnp.ndarray],
    x: jnp.ndarray,           # block 0: tokens i32 [B, S]; else f32 [B, S, d]
    k_cache: jnp.ndarray,     # [nl_b, B, max_seq, H, Dh]
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,         # scalar i32
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Forward one model block; returns (out, k_cache', v_cache').

    `out` is hidden states [B, S, d] for inner blocks and logits
    [B, S, vocab] for the final block.
    """
    names = [n for n, _ in block_param_specs(cfg, block)]
    p = dict(zip(names, params))
    lo, hi = cfg.block_layer_range(block)

    if block == 0:
        x = p["tok_embed"][x]  # [B, S, d]

    new_k, new_v = [], []
    for li, layer in enumerate(range(lo, hi)):
        pre = f"layer{layer}."
        h = _rmsnorm(x, p[pre + "attn_norm"], cfg.norm_eps, use_pallas)
        attn, kc, vc = _attention(
            cfg, h, p[pre + "wq"], p[pre + "wk"], p[pre + "wv"], p[pre + "wo"],
            k_cache[li], v_cache[li], pos, use_pallas)
        new_k.append(kc)
        new_v.append(vc)
        x = x + attn
        h = _rmsnorm(x, p[pre + "ffn_norm"], cfg.norm_eps, use_pallas)
        x = x + _mlp(h, p[pre + "w1"], p[pre + "w2"], p[pre + "w3"], use_pallas)

    if block == cfg.n_blocks - 1:
        x = _rmsnorm(x, p["final_norm"], cfg.norm_eps, use_pallas)
        x = _mm(x, p["lm_head"], use_pallas)

    return x, jnp.stack(new_k), jnp.stack(new_v)


def make_block_fn(cfg: ModelConfig, block: int, use_pallas: bool = True):
    """Flat-signature closure for AOT lowering:
    fn(*weights, x, k_cache, v_cache, pos) -> (out, k_cache', v_cache')."""
    n_params = len(block_param_specs(cfg, block))

    def fn(*args):
        params = list(args[:n_params])
        x, k_cache, v_cache, pos = args[n_params:]
        return block_forward(cfg, block, params, x, k_cache, v_cache, pos, use_pallas)

    return fn


def init_caches(cfg: ModelConfig, batch: int) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Zeroed per-block KV caches: [nl_b, B, max_seq, H, Dh] each."""
    caches = []
    for b in range(cfg.n_blocks):
        lo, hi = cfg.block_layer_range(b)
        shape = (hi - lo, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        caches.append((jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)))
    return caches


# ---------------------------------------------------------------------------
# Whole-model helpers (oracle / golden generation; never lowered)
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params: List[List[jnp.ndarray]],
    x: jnp.ndarray,
    caches: List[Tuple[jnp.ndarray, jnp.ndarray]],
    pos: jnp.ndarray,
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, List[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """Chain all blocks; returns (logits, new_caches)."""
    new_caches = []
    out = x
    for b in range(cfg.n_blocks):
        kc, vc = caches[b]
        out, kc, vc = block_forward(cfg, b, params[b], out, kc, vc, pos, use_pallas)
        new_caches.append((kc, vc))
    return out, new_caches


def generate(
    cfg: ModelConfig,
    params: List[List[jnp.ndarray]],
    prompt: jnp.ndarray,  # [B, P] i32
    n_tokens: int,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Greedy decode: returns [B, n_tokens] generated token ids."""
    batch, p_len = prompt.shape
    caches = init_caches(cfg, batch)
    logits, caches = forward(cfg, params, prompt, caches, jnp.int32(0), use_pallas)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out = [tok]
    for step in range(1, n_tokens):
        pos = jnp.int32(p_len + step - 1)
        logits, caches = forward(cfg, params, tok[:, None], caches, pos, use_pallas)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
