"""Pallas tiled matmul kernel (L1): MXU-oriented blocked GEMM.

The paper's MLP/projection GEMMs hit tensor cores on H800; the TPU analogue
is the MXU systolic array fed from VMEM. The grid is (M/bm, N/bn, K/bk) with
the K dimension innermost so the output tile stays resident in VMEM across
the K reduction (revisited-output accumulation — the Pallas idiom for the
CUDA "accumulate in registers per threadblock" pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def _pick_tile(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is <= pref (tiles must divide the shape)."""
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jnp.ndarray,  # [M, K]
    y: jnp.ndarray,  # [K, N]
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    """Blocked x @ y with f32 accumulation. Tiles clamp to divisors of the shape."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _pick_tile(m, bm)
    bn = _pick_tile(n, bn)
    bk = _pick_tile(k, bk)

    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)
