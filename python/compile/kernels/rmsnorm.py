"""Pallas fused RMSNorm kernel (L1): one VMEM-resident pass per row block.

Fuses the square-reduce, rsqrt and scale that would otherwise be three HLO
ops with HBM round-trips; on TPU the row block sits in VMEM for the whole
kernel (the CUDA equivalent keeps the row in shared memory / registers).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(var + eps)
    o_ref[...] = (x * inv * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def rmsnorm(
    x: jnp.ndarray,  # [R, d]
    w: jnp.ndarray,  # [d]
    block_rows: int = 8,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """RMSNorm over the last axis of a 2D input."""
    r, d = x.shape
    br = min(block_rows, r)
    while r % br != 0:
        br -= 1

    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, w)
