"""Pallas flash-decode attention kernel (L1 hot spot).

Hardware adaptation (paper §CUDA → TPU, see DESIGN.md §Hardware-Adaptation):
the paper runs Llama attention on H800s where flash-attention stages KV tiles
through shared memory per threadblock. On TPU the analogous schedule is
expressed with a Pallas grid over (batch*heads) and an inner loop that streams
KV cache blocks HBM→VMEM, maintaining an online-softmax accumulator in VMEM
registers. `BlockSpec` carries the HBM↔VMEM schedule that threadblocks carry
in CUDA.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO via the Pallas interpreter. The
*structure* (grid, block streaming, online softmax) is the TPU design; see
DESIGN.md / EXPERIMENTS.md for the VMEM/MXU estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, max_seq: int):
    """One (batch, head) program: attend q (1 token) over cache[0..pos].

    Ref block shapes:
      pos_ref: [1]        (i32; last valid cache index, attend 0..pos inclusive)
      q_ref:   [1, 1, D]
      k_ref:   [1, S, D]  (full per-head cache buffer resident for this program)
      v_ref:   [1, S, D]
      o_ref:   [1, 1, D]
    """
    d = q_ref.shape[-1]
    scale = 1.0 / (d ** 0.5)
    q = q_ref[0, :, :].astype(jnp.float32) * scale  # [1, D]
    pos = pos_ref[0]
    # Only visit KV blocks that contain valid entries: ceil((pos+1)/block_k).
    n_blocks = (pos + 1 + block_k - 1) // block_k

    def body(i, carry):
        m, l, acc = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(i * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(i * block_k, block_k), slice(None)))
        s = jnp.dot(q, k_blk.astype(jnp.float32).T)  # [1, block_k]
        idx = i * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(idx <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v_blk.astype(jnp.float32))
        return m_new, l_new, acc_new

    m0 = jnp.full((1, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1, 1), jnp.float32)
    acc0 = jnp.zeros((1, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0, :, :] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def attention_decode(
    q: jnp.ndarray,  # [B, H, 1, D]
    k: jnp.ndarray,  # [B, H, S, D]
    v: jnp.ndarray,  # [B, H, S, D]
    pos: jnp.ndarray,  # scalar i32
    block_k: int = 32,
) -> jnp.ndarray:
    """Flash-decode attention: softmax(q kᵀ/√d + causal mask) v, streamed by KV block."""
    b, h, _, d = q.shape
    s = k.shape[2]
    block_k = min(block_k, s)
    assert s % block_k == 0, f"max_seq {s} must be divisible by block_k {block_k}"
    qf = q.reshape(b * h, 1, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(1), (1,))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, max_seq=s),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, 1, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        interpret=True,
    )(pos_arr, qf, kf, vf)
    return out.reshape(b, h, 1, d)
