"""Pure-jnp reference oracle for every Pallas kernel (L1 correctness spec).

These functions define the semantics the Pallas kernels must reproduce.
pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis and
asserts allclose between each kernel and its oracle here.
"""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis: x * rsqrt(mean(x^2) + eps) * w."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def matmul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul, f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def attention_decode_ref(
    q: jnp.ndarray,  # [B, H, 1, D]
    k: jnp.ndarray,  # [B, H, S, D]  (full cache buffer)
    v: jnp.ndarray,  # [B, H, S, D]
    pos: jnp.ndarray,  # scalar i32: attend to positions 0..pos inclusive
) -> jnp.ndarray:
    """Single-token decode attention against a (masked) KV cache buffer."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    idx = jnp.arange(k.shape[2])
    mask = idx[None, None, None, :] <= pos
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attention_prefill_ref(
    q: jnp.ndarray,  # [B, H, S, D]
    k: jnp.ndarray,  # [B, H, S, D]
    v: jnp.ndarray,  # [B, H, S, D]
) -> jnp.ndarray:
    """Causal self-attention over a fresh prompt of length S."""
    d = q.shape[-1]
    s_len = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    i = jnp.arange(s_len)[:, None]
    j = jnp.arange(s_len)[None, :]
    s = jnp.where(j <= i, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def swiglu_ref(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray) -> jnp.ndarray:
    """Llama MLP: (silu(x @ w1) * (x @ w3)) @ w2."""
    a = jnp.matmul(x, w1)
    b = jnp.matmul(x, w3)
    return jnp.matmul(a * (1.0 / (1.0 + jnp.exp(-a))) * b, w2)
