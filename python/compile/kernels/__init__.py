"""L1: Pallas kernels for the transformer hot spots + pure-jnp oracle."""

from .attention import attention_decode
from .matmul import matmul
from .rmsnorm import rmsnorm
from . import ref

__all__ = ["attention_decode", "matmul", "rmsnorm", "ref"]
