//! Multicast algorithm shoot-out on the simulated Testbed1 fabric:
//! binomial pipeline (λScale) vs binary tree (FaaSNet) vs ring (NCCL-like),
//! with per-node completion timelines and the k-way effect.
//!
//! ```sh
//! cargo run --release --example multicast_demo [model] [nodes] [blocks]
//! ```

use lambda_scale::config::NetworkConfig;
use lambda_scale::model::ModelSpec;
use lambda_scale::multicast::{build_plan, Algorithm, NodeId};
use lambda_scale::pipeline::generation::{
    generate_pipelines, pipeline_block_assignment, pipeline_ready_time,
};
use lambda_scale::multicast::kway::split_subgroups;
use lambda_scale::sim::transfer::{Tier, TransferOpts};
use lambda_scale::util::bench::Table;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let model = args
        .get(1)
        .and_then(|s| ModelSpec::by_name(s))
        .unwrap_or_else(ModelSpec::llama2_13b);
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let b: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(16);

    let net = NetworkConfig::default();
    let part = model.partition(b);
    let bytes = part.block_bytes();
    let nodes: Vec<NodeId> = (0..n).collect();

    println!(
        "model {} ({:.1} GB) → {} nodes as {} blocks over {} GB/s RDMA\n",
        model.name,
        model.bytes as f64 / 1e9,
        n,
        part.n_blocks(),
        net.rdma_gbps
    );

    let mut t = Table::new(&["algorithm", "first node done (s)", "all nodes done (s)"]);
    for alg in [
        Algorithm::LambdaScale { k: 1 },
        Algorithm::FaasNet,
        Algorithm::Nccl,
        Algorithm::ServerlessLlm,
    ] {
        let plan = build_plan(alg, &nodes, 1, part.n_blocks(), Tier::Gpu, &net);
        let log = plan.execute(&net, TransferOpts::default(), &bytes);
        let dests = &nodes[1..];
        let first = dests
            .iter()
            .filter_map(|&d| log.node_complete(d, part.n_blocks()))
            .min()
            .map(|t| t.as_secs())
            .unwrap_or(f64::NAN);
        let all = log.all_complete(&nodes, part.n_blocks()).map(|t| t.as_secs()).unwrap_or(f64::NAN);
        t.row(&[alg.name(), format!("{first:.3}"), format!("{all:.3}")]);
    }
    t.print();

    // Execute-while-load: when do λPipe execution pipelines come up?
    println!("\nλPipe execution pipelines (k=2):");
    let k = 2.min(n - 1);
    let plan = build_plan(Algorithm::LambdaScale { k }, &nodes, k, part.n_blocks(), Tier::Gpu, &net);
    let log = plan.execute(&net, TransferOpts::default(), &bytes);
    let groups = split_subgroups(&nodes[k..], k);
    let full = log.all_complete(&nodes, part.n_blocks()).unwrap();
    for p in generate_pipelines(&groups) {
        let asn = pipeline_block_assignment(&p, part.n_blocks(), k);
        if let Some(ready) = pipeline_ready_time(&log, &asn) {
            let members: Vec<String> = p.iter().map(|&(n, _)| format!("n{n}")).collect();
            println!(
                "  pipeline [{}] ready at {:.3}s ({:.0}% of full load {:.3}s)",
                members.join(","),
                ready.as_secs(),
                100.0 * ready.as_secs() / full.as_secs(),
                full.as_secs()
            );
        }
    }
}
