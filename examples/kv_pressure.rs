//! KV pressure: paged KV residency fighting a second tenant's pinned
//! weights inside one bounded GPU byte budget.
//!
//! Tenant A (Llama-2 13B, long generations) serves on node 0; tenant B
//! (7B) pins its weights on node 1 of a 2-node cluster. With the kvcache
//! subsystem on, A's instance carves its paged KV pool out of whatever
//! GPU headroom its node has left after weights. Tightening
//! `gpu_capacity_bytes` squeezes from both sides: B's pinned weights deny
//! A a second replica (26 GB will not fit next to 13.5 GB under a small
//! cap), and A's own 26 GB leave only slivers for KV — so long decodes
//! exhaust the pool, the youngest requests get preempted, and their
//! recompute/swap stalls land in the tail.
//!
//! A/B: the same workload under an unbounded budget (pool sized to the
//! configured context cap — zero preemptions) vs. a tight one. Compare
//! the preemption counters and the tail-latency delta.
//!
//! ```sh
//! cargo run --release --example kv_pressure [gpu_cap_gb]
//! ```
//!
//! The default 28 GB per node leaves A ≈2 GB of KV headroom — about 150
//! blocks of 16 tokens — while the burst's steady-state wants ≈190.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{ServingSession, SessionReport, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::bench::Table;
use lambda_scale::util::stats::Samples;
use lambda_scale::workload::{Request, Trace};

/// Deterministic long-decode burst: `n` requests, fixed 128-token prompts
/// and 256-token outputs (exact sizes so both A/B runs see identical work).
fn long_burst(n: usize, model: &str) -> Trace {
    Trace {
        requests: (0..n)
            .map(|i| Request {
                id: i as u64,
                arrival: SimTime::ZERO,
                model: model.to_string(),
                prompt_tokens: 128,
                output_tokens: 256,
            })
            .collect(),
    }
}

fn run(gpu_cap_bytes: u64) -> SessionReport {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 2;
    cluster.kv.block_tokens = 16;
    ServingSession::builder()
        .cluster(cluster)
        .gpu_capacity_bytes(gpu_cap_bytes)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .keep_alive(30.0)
        .trace(long_burst(32, "llama2-13b"))
        .model(ModelSpec::llama2_7b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .keep_alive(30.0)
        .trace(long_burst(16, "llama2-7b"))
        .run()
}

fn main() {
    let gpu_cap_gb: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(28.0);
    println!(
        "two tenants, 2 nodes, kv_block_tokens = 16; tenant A (13B) decodes 256-token\n\
         outputs while tenant B (7B) pins its weights — GPU cap {gpu_cap_gb} GB/node\n\
         vs unbounded\n"
    );

    let roomy = run(u64::MAX);
    let tight = run((gpu_cap_gb * 1e9) as u64);

    let mut t = Table::new(&[
        "gpu cap / node",
        "model",
        "served",
        "p50 lat (s)",
        "p90 lat (s)",
        "p99 lat (s)",
        "preempt",
        "recomp",
        "swap",
        "kv util peak",
    ]);
    for (label, report) in [("unbounded", &roomy), ("tight", &tight)] {
        for m in &report.models {
            let mut lat = Samples::new();
            for r in &m.metrics.requests {
                lat.push(r.latency());
            }
            t.row(&[
                label.to_string(),
                m.model.clone(),
                format!("{}", m.completed),
                format!("{:.3}", lat.p50()),
                format!("{:.3}", lat.p90()),
                format!("{:.3}", lat.p99()),
                format!("{}", m.metrics.kv_preemptions),
                format!("{}", m.metrics.kv_recomputes),
                format!("{}", m.metrics.kv_swaps),
                format!("{:.2}", m.metrics.kv_util_peak()),
            ]);
        }
    }
    t.print();

    let p90 = |r: &SessionReport| {
        let mut s = Samples::new();
        for q in &r.models[0].metrics.requests {
            s.push(q.latency());
        }
        s.p90()
    };
    let delta = p90(&tight) - p90(&roomy);
    let preempts = tight.models[0].metrics.kv_preemptions;
    println!(
        "\ntenant A p90 latency delta: {delta:+.3}s with {preempts} preemption(s) ({})",
        if preempts > 0 {
            "KV pool exhausted under the tight cap — youngest decodes paid the KvSwitch stall"
        } else {
            "no KV pressure at this cap; try a smaller one"
        }
    );
    let stalled: Vec<u64> = tight.models[0]
        .metrics
        .requests
        .iter()
        .filter(|r| r.kv_preemptions > 0)
        .map(|r| r.id)
        .collect();
    if !stalled.is_empty() {
        println!("preempted request ids (tight run): {stalled:?}");
    }
}
