//! Scaling-policy A/B: the same bursty trace under λPipe with each of the
//! three `ScalingPolicy` impls, scored on tail latency, SLO attainment and
//! priced cost.
//!
//! The reactive window only reacts once the backlog exists; the SLO-aware
//! policy over-provisions while the observed p99 TTFT is blown and refuses
//! keep-alive reclaims until the tail recovers (more GPU·s, better tail);
//! the predictive EWMA pre-warms when its fast rate estimate pulls ahead
//! of the slow one, paying for capacity *before* the spike peaks.
//!
//! ```sh
//! cargo run --release --example scaling_policies [slo_ttft_s]
//! ```
//!
//! Tighten the target (say `0.8`) and watch the slo-aware column trade
//! dollars for attainment; loosen it (`10`) and all three collapse to
//! near-identical reactive behavior.

use lambda_scale::config::ScalerKind;
use lambda_scale::coordinator::SystemKind;
use lambda_scale::eval::{run_cell, trace_matrix, EvalConfig};
use lambda_scale::util::bench::Table;

fn main() {
    let slo_ttft_s: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2.5);
    let cfg = EvalConfig { duration_s: 300.0, slo_ttft_s, ..Default::default() };
    let traces = trace_matrix(&cfg);
    let (name, bursty) = &traces[0];
    println!(
        "λPipe (k=2) on the {name} trace: {} requests over {:.0}s, SLO TTFT ≤ {:.2}s\n",
        bursty.len(),
        cfg.duration_s,
        cfg.slo_ttft_s
    );
    let mut t = Table::new(&[
        "scaler", "served", "p50 TTFT (s)", "p99 TTFT (s)", "SLO att.", "GPU·s", "cost ($)",
    ]);
    for kind in [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma] {
        let cell = run_cell(&cfg, name, bursty, SystemKind::LambdaScale { k: 2 }, kind);
        t.row(&[
            cell.scaler,
            format!("{}/{}", cell.completed, cell.requests),
            format!("{:.3}", cell.p50_ttft_s),
            format!("{:.3}", cell.p99_ttft_s),
            format!("{:.1}%", cell.slo_attainment * 100.0),
            format!("{:.0}", cell.gpu_seconds),
            format!("{:.4}", cell.cost_usd),
        ]);
    }
    t.print();
    println!(
        "\n(the full 3 traces × 3 backends × 3 policies matrix: `lambda-scale eval`,\n\
         which also writes BENCH_eval.json + RESULTS.md — see docs/EVALUATION.md)"
    );
}
