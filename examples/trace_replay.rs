//! END-TO-END DRIVER (DESIGN.md §6): the full λScale stack on real compute.
//!
//! Four logical workers each own a PJRT engine. A binomial-pipeline
//! multicast (simulated on the Testbed1 fabric, time-scaled to wall clock)
//! delivers the tiny-Llama model's four blocks; the coordinator
//!
//!   1. forms a λPipe **execution pipeline** as soon as worker *w* holds
//!      block *w* — requests start decoding across workers while the rest
//!      of the model is still in flight (execute-while-load);
//!   2. **mode-switches** when the multicast completes: in-flight requests
//!      are redistributed to workers, their KV caches **recomputed** from
//!      prompt + already-generated tokens (§4.4), and decoding continues
//!      locally;
//!   3. verifies the pipelined + switched generation is **token-identical**
//!      to pure local generation (greedy decode is deterministic, so any
//!      divergence is a coordination bug).
//!
//! Reports TTFT and throughput per phase. Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example trace_replay
//! ```

use lambda_scale::multicast::binomial::binomial_plan;
use lambda_scale::config::NetworkConfig;
use lambda_scale::runtime::{argmax, tokenizer, Engine, Phase};
use lambda_scale::sim::transfer::{Tier, TransferOpts};
use std::time::Instant;

const N_WORKERS: usize = 4;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let t_start = Instant::now();

    // ---- plan the multicast on the simulated fabric -----------------------
    // Source node 0 holds the model; workers are nodes 1..=4.
    let probe = Engine::new(&dir)?;
    let cfg = probe.manifest.config.clone();
    anyhow::ensure!(cfg.n_blocks == N_WORKERS, "demo assumes {} blocks", N_WORKERS);
    let block_bytes: Vec<u64> =
        probe.manifest.blocks.iter().map(|b| b.weights_bytes as u64).collect();
    drop(probe);

    let net = NetworkConfig::default();
    let nodes: Vec<usize> = (0..=N_WORKERS).collect();
    let plan = binomial_plan(&nodes, cfg.n_blocks, Tier::Gpu);
    let log = plan.execute(&net, TransferOpts::default(), &block_bytes);
    let sim_finish = log.all_complete(&nodes, cfg.n_blocks).unwrap().as_secs();
    // Scale sim time to wall clock so the load window spans several decode
    // steps (the tiny model's real bytes would arrive in ~1 ms).
    let time_scale = 20.0 / sim_finish;
    println!(
        "multicast plan: {} blocks to {} workers, sim finish {:.3} ms → scaled to {:.1}s window",
        cfg.n_blocks,
        N_WORKERS,
        sim_finish * 1e3,
        sim_finish * time_scale
    );

    // Block arrival wall-clock deadlines per worker (worker w = node w+1).
    let arrival = |w: usize, b: usize| -> f64 {
        log.arrivals.get(&(w + 1, b)).map(|t| t.as_secs() * time_scale).unwrap_or(f64::MAX)
    };

    // ---- workers -----------------------------------------------------------
    println!("spinning up {N_WORKERS} workers (PJRT CPU clients)...");
    let mut workers: Vec<Engine> = (0..N_WORKERS).map(|_| Engine::new(&dir)).collect::<Result<_, _>>()?;
    // Pre-initialize executables (§5 pre-allocation): block arrival then
    // costs only the weight install, like a real GDR transfer.
    let t_compile = Instant::now();
    for eng in workers.iter_mut() {
        for b in 0..cfg.n_blocks {
            eng.precompile_block(b)?;
        }
    }
    println!("executables pre-compiled in {:.1}s", t_compile.elapsed().as_secs_f64());

    // ---- workload ----------------------------------------------------------
    let batch = *probe_batches(&dir)?.iter().max().unwrap();
    let n_requests = 2 * batch;
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| tokenizer::encode_padded(&format!("request {i}: scale me up"), cfg.vocab, cfg.prefill_len))
        .collect();
    let pipeline_tokens = 8usize; // decoded while loading
    let local_tokens = 8usize; // decoded after mode switch
    println!("workload: {n_requests} requests (batch {batch}), {} tokens each\n", pipeline_tokens + local_tokens);

    // Reference: pure local generation for the consistency check (per
    // batch-group, matching the artifact batch size).
    let reference = {
        let full = Engine::new_full(&dir)?;
        let mut out = Vec::new();
        for g in 0..n_requests / batch {
            out.extend(full.generate(
                &prompts[g * batch..(g + 1) * batch],
                pipeline_tokens + local_tokens,
            )?);
        }
        out
    };

    // ---- phase 1: execute-while-load (pipelined) ----------------------------
    // Stage b of the pipeline runs on the worker that receives block b
    // earliest (Alg 2's role: build the pipeline the multicast makes ready
    // first). Brute-force the 4! assignments on the simulated arrival log.
    let stage_worker: Vec<usize> = {
        let mut best: (f64, Vec<usize>) = (f64::MAX, (0..N_WORKERS).collect());
        let mut perm: Vec<usize> = (0..N_WORKERS).collect();
        permute(&mut perm, 0, &mut |p: &[usize]| {
            let ready = (0..N_WORKERS)
                .map(|b| arrival(p[b], b))
                .fold(0.0f64, f64::max);
            if ready < best.0 {
                best = (ready, p.to_vec());
            }
        });
        println!(
            "pipeline stage→worker assignment {:?} (ready at {:.1}s of {:.1}s full load)",
            best.1,
            best.0,
            sim_finish * time_scale
        );
        best.1
    };
    let load_t0 = Instant::now();
    let mut ttft: Vec<Option<f64>> = vec![None; n_requests];
    let install_due = |workers: &mut [Engine], now: f64| -> anyhow::Result<usize> {
        let mut n = 0;
        for (w, eng) in workers.iter_mut().enumerate() {
            for b in 0..cfg.n_blocks {
                if !eng.has_block(b) && arrival(w, b) <= now {
                    eng.install_block(b)?;
                    n += 1;
                }
            }
        }
        Ok(n)
    };

    // Wait (installing) until the pipeline diagonal is ready.
    loop {
        let now = load_t0.elapsed().as_secs_f64();
        install_due(&mut workers, now)?;
        if (0..N_WORKERS).all(|b| workers[stage_worker[b]].has_block(b)) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let pipeline_ready = load_t0.elapsed().as_secs_f64();
    println!("λPipe execution pipeline ready at {pipeline_ready:.2}s (full load at {:.2}s)", sim_finish * time_scale);

    // Run both request groups through the pipeline: prefill + decode.
    let mut sessions: Vec<Vec<lambda_scale::runtime::Session>> = Vec::new(); // [group][worker]
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); n_requests];
    let mut last_tok: Vec<Vec<i32>> = Vec::new(); // per group
    let pipe_t0 = Instant::now();
    let mut pipe_token_count = 0usize;
    for g in 0..2 {
        let group = &prompts[g * batch..(g + 1) * batch];
        let flat: Vec<i32> = group.iter().flatten().copied().collect();
        let mut ws: Vec<lambda_scale::runtime::Session> =
            workers.iter().map(|e| e.session(batch)).collect::<Result<_, _>>()?;
        // Pipelined prefill: stage b on its assigned worker.
        let mut x = xla::Literal::vec1(&flat).reshape(&[batch as i64, cfg.prefill_len as i64])?;
        for b in 0..N_WORKERS {
            let w = stage_worker[b];
            x = workers[w].run_block(b, Phase::Prefill, &mut ws[w], &x)?;
        }
        for s in ws.iter_mut() {
            s.pos = cfg.prefill_len;
        }
        let logits = x.to_vec::<f32>()?;
        let toks: Vec<i32> = (0..batch)
            .map(|b| {
                let base = (b * cfg.prefill_len + cfg.prefill_len - 1) * cfg.vocab;
                argmax(&logits[base..base + cfg.vocab])
            })
            .collect();
        for (b, &t) in toks.iter().enumerate() {
            let r = g * batch + b;
            generated[r].push(t);
            ttft[r].get_or_insert(load_t0.elapsed().as_secs_f64());
        }
        pipe_token_count += batch;
        last_tok.push(toks);
        sessions.push(ws);
    }
    // Pipelined decode until the multicast completes (2D: group A on early
    // blocks while group B follows — serialized here for clarity).
    for _step in 1..pipeline_tokens {
        let now = load_t0.elapsed().as_secs_f64();
        install_due(&mut workers, now)?;
        for g in 0..2 {
            let ws = &mut sessions[g];
            let mut x = xla::Literal::vec1(&last_tok[g]).reshape(&[batch as i64, 1])?;
            for b in 0..N_WORKERS {
                let w = stage_worker[b];
                x = workers[w].run_block(b, Phase::Decode, &mut ws[w], &x)?;
            }
            let pos_next = ws[0].pos + 1;
            for s in ws.iter_mut() {
                s.pos = pos_next;
            }
            let logits = x.to_vec::<f32>()?;
            let toks: Vec<i32> =
                (0..batch).map(|b| argmax(&logits[b * cfg.vocab..(b + 1) * cfg.vocab])).collect();
            for (b, &t) in toks.iter().enumerate() {
                generated[g * batch + b].push(t);
            }
            pipe_token_count += batch;
            last_tok[g] = toks;
        }
    }
    let pipe_dt = pipe_t0.elapsed().as_secs_f64();
    println!(
        "phase 1 (execute-while-load): {} tokens across the 4-worker pipeline in {:.2}s ({:.1} tok/s)",
        pipe_token_count,
        pipe_dt,
        pipe_token_count as f64 / pipe_dt
    );

    // ---- phase 2: mode switch + local execution -----------------------------
    // Finish the multicast, then redistribute: group g moves to worker g
    // (even spread) and its KV cache is *recomputed* from prompt+generated.
    loop {
        let now = load_t0.elapsed().as_secs_f64();
        install_due(&mut workers, now)?;
        if workers.iter().all(|w| w.is_complete()) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    println!("multicast complete at {:.2}s — mode switching (KV recompute)", load_t0.elapsed().as_secs_f64());

    let switch_t0 = Instant::now();
    let mut local_sessions = Vec::new();
    for g in 0..2 {
        let eng = &workers[g]; // request group g lands on worker g
        let mut s = eng.session(batch)?;
        // KV recompute (§4.4): replay prompt, then generated tokens.
        let flat: Vec<i32> =
            prompts[g * batch..(g + 1) * batch].iter().flatten().copied().collect();
        eng.prefill(&mut s, &flat)?;
        for step in 0..pipeline_tokens - 1 {
            let toks: Vec<i32> =
                (0..batch).map(|b| generated[g * batch + b][step]).collect();
            eng.decode(&mut s, &toks)?;
        }
        local_sessions.push(s);
    }
    let switch_dt = switch_t0.elapsed().as_secs_f64();
    println!("mode switch stall (KV recompute for {} requests): {:.2}s", n_requests, switch_dt);

    let local_t0 = Instant::now();
    let mut local_token_count = 0usize;
    for g in 0..2 {
        let eng = &workers[g];
        let s = &mut local_sessions[g];
        let mut toks: Vec<i32> = (0..batch).map(|b| generated[g * batch + b][pipeline_tokens - 1]).collect();
        for _ in 0..local_tokens {
            let logits = eng.decode(s, &toks)?;
            toks = logits.iter().map(|l| argmax(l)).collect();
            for (b, &t) in toks.iter().enumerate() {
                generated[g * batch + b].push(t);
            }
            local_token_count += batch;
        }
    }
    let local_dt = local_t0.elapsed().as_secs_f64();
    println!(
        "phase 2 (local mode): {} tokens on 2 local replicas in {:.2}s ({:.1} tok/s)",
        local_token_count,
        local_dt,
        local_token_count as f64 / local_dt
    );

    // ---- consistency check ---------------------------------------------------
    let mut mismatches = 0;
    for r in 0..n_requests {
        if generated[r] != reference[r] {
            mismatches += 1;
            eprintln!("request {r}: pipelined {:?} != local {:?}", generated[r], reference[r]);
        }
    }
    anyhow::ensure!(mismatches == 0, "{mismatches} requests diverged from local execution");
    println!("\nconsistency: all {} requests token-identical to pure local execution ✓", n_requests);

    let mut ttfts: Vec<f64> = ttft.into_iter().flatten().collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "TTFT from spike start (load window included): p50 {:.2}s, max {:.2}s; total wall time {:.1}s",
        ttfts[ttfts.len() / 2],
        ttfts.last().unwrap(),
        t_start.elapsed().as_secs_f64()
    );
    Ok(())
}

fn probe_batches(dir: &str) -> anyhow::Result<Vec<usize>> {
    let m = lambda_scale::runtime::Manifest::load(dir)?;
    Ok(m.batch_sizes())
}

/// Heap's algorithm, calling `f` on every permutation of `xs`.
fn permute(xs: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == xs.len() {
        f(xs);
        return;
    }
    for i in k..xs.len() {
        xs.swap(k, i);
        permute(xs, k + 1, f);
        xs.swap(k, i);
    }
}
