//! Quickstart: load the AOT artifacts and serve a real generation request
//! through the Rust PJRT runtime (local execution mode).
//!
//! ```sh
//! make artifacts            # once: python AOT compile
//! cargo run --release --example quickstart
//! ```

use lambda_scale::runtime::{tokenizer, Engine};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading artifacts from {dir}/ ...");
    let t0 = Instant::now();
    let engine = Engine::new_full(&dir)?;
    let cfg = &engine.manifest.config;
    println!(
        "model ready in {:.1}s: {} params, {} layers, {} blocks, vocab {}",
        t0.elapsed().as_secs_f64(),
        cfg.param_count,
        cfg.n_layers,
        cfg.n_blocks,
        cfg.vocab
    );

    let prompt_text = "Hello, λScale!";
    let prompt = vec![tokenizer::encode_padded(prompt_text, cfg.vocab, cfg.prefill_len)];
    let n_tokens = 32.min(cfg.max_seq - cfg.prefill_len);

    let t1 = Instant::now();
    let toks = engine.generate(&prompt, n_tokens)?;
    let dt = t1.elapsed().as_secs_f64();

    println!("prompt:  {prompt_text:?}");
    println!("tokens:  {:?}", toks[0]);
    println!("decoded: {:?}", tokenizer::decode(&toks[0]));
    println!(
        "generated {} tokens in {:.2}s ({:.1} tok/s, real PJRT execution, single sequence)",
        n_tokens,
        dt,
        n_tokens as f64 / dt
    );
    println!("\n(The model is tiny and random-initialized — output text is gibberish by design;");
    println!(" the point is the full Rust→PJRT→per-block-HLO serving path.)");
    Ok(())
}
