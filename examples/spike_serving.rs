//! Spike handling: replay a load spike against λScale and every baseline on
//! the simulated Testbed1 cluster; report TTFT distribution, ramp speed and
//! GPU cost side by side (the §7.3/§7.4 experiment as a single command).
//!
//! Each run goes through the trait-based `ServingSession` builder — the
//! same path a custom `ScalingBackend` / `RoutingPolicy` /
//! `AdmissionPolicy` would plug into.
//!
//! ```sh
//! cargo run --release --example spike_serving [model] [n_requests]
//! ```

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{ServingSession, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::bench::Table;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::burst_trace;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let model = args
        .get(1)
        .and_then(|s| ModelSpec::by_name(s))
        .unwrap_or_else(ModelSpec::llama2_13b);
    let n_req: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100);

    let mut rng = Rng::new(7);
    let trace = burst_trace(n_req, 0.0, &model.name, 128, 64, &mut rng);
    println!(
        "spike: {n_req} simultaneous requests for {} on an 8-node Testbed1 cluster\n",
        model.name
    );

    let mut t = Table::new(&[
        "system", "p50 TTFT (s)", "p90 TTFT (s)", "max TTFT (s)", "GPU·s (60s)", "peak GPUs",
    ]);
    for sys in [
        SystemKind::LambdaScale { k: 1 },
        SystemKind::LambdaScale { k: 2 },
        SystemKind::LambdaScale { k: 4 },
        SystemKind::FaasNet,
        SystemKind::Nccl,
        SystemKind::ServerlessLlm,
        SystemKind::Ideal,
    ] {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        let gpu_sources = match sys {
            SystemKind::LambdaScale { k } => k.min(4),
            _ => 1,
        };
        let m = ServingSession::builder()
            .cluster(cluster)
            .model(model.clone())
            .system(sys)
            .max_batch(8)
            .initial_gpu_sources(gpu_sources)
            .trace(trace.clone())
            .run()
            .into_single();
        let mut s = m.ttft_samples();
        let peak = m.gpu_series(1.0, 60.0).iter().map(|&(_, g)| g).max().unwrap_or(0);
        t.row(&[
            sys.name(),
            format!("{:.3}", s.p50()),
            format!("{:.3}", s.p90()),
            format!("{:.3}", s.max()),
            format!("{:.0}", m.gpu_time(SimTime::from_secs(60.0))),
            peak.to_string(),
        ]);
    }
    t.print();
    println!("\npaper shape: λScale's p90 improves with k; ServerlessLLM pays SSD loading;");
    println!("FaaSNet/NCCL wait for full models before serving (no execute-while-load).");
}
