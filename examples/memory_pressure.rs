//! Memory pressure: two tenants fighting over a bounded host-memory tier.
//!
//! Tenant A (Llama-2 13B) bursts, scales out, and its replicas are
//! reclaimed into host memory — warm for the next burst. Tenant B (7B)
//! then bursts on the same nodes; when *its* replicas are reclaimed, the
//! GPU→host demotion must fit in the node's bounded host cache, and the
//! cluster-wide `MemoryManager` evicts tenant A's warm copy to make room.
//! A's re-burst then loads from SSD (5 GB/s) instead of host memory
//! (64 GB/s): keep-alive warmth is a contended resource, not a property of
//! a single tenant (λScale §2.3 / §5).
//!
//! ```sh
//! cargo run --release --example memory_pressure [host_cap_gb]
//! ```
//!
//! The default 30 GB per node holds A's 26 GB copy *or* leaves room for
//! B's 13.5 GB demotion — not both. Pass a big value (say 1000) and the
//! contended column collapses back to the warm baseline.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{SessionReport, ServingSession, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::util::bench::Table;
use lambda_scale::util::rng::Rng;
use lambda_scale::util::stats::Samples;
use lambda_scale::workload::{burst_trace, Trace};

const REBURST_AT: f64 = 70.0;

fn two_burst_trace(n: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut trace = burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng);
    let again = burst_trace(n, REBURST_AT, "llama2-13b", 128, 64, &mut rng);
    trace.merge(&again, lambda_scale::sim::time::SimTime::ZERO);
    trace
}

fn run(host_cap_bytes: u64) -> SessionReport {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 4;
    ServingSession::builder()
        .cluster(cluster)
        .host_capacity_bytes(host_cap_bytes)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .keep_alive(5.0)
        .trace(two_burst_trace(128, 3))
        .model(ModelSpec::llama2_7b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .keep_alive(5.0)
        .trace(burst_trace(128, 25.0, "llama2-7b", 96, 48, &mut Rng::new(4)))
        .run()
}

fn reburst_ttfts(report: &SessionReport) -> Samples {
    let mut s = Samples::new();
    for r in &report.models[0].metrics.requests {
        if r.arrival.as_secs() >= REBURST_AT {
            s.push(r.ttft());
        }
    }
    s
}

fn main() {
    let host_cap_gb: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30.0);
    println!(
        "two tenants, 4 nodes; tenant A re-bursts at t={REBURST_AT}s after tenant B's\n\
         reclaim demoted into the shared host tier ({host_cap_gb} GB/node vs unbounded)\n"
    );

    let unbounded = run(u64::MAX);
    let bounded = run((host_cap_gb * 1e9) as u64);

    let mut warm = reburst_ttfts(&unbounded);
    let mut cold = reburst_ttfts(&bounded);

    let mut t = Table::new(&[
        "host cap / node",
        "re-burst p50 TTFT (s)",
        "p90 (s)",
        "p99 (s)",
        "max (s)",
    ]);
    t.row(&[
        "unbounded".to_string(),
        format!("{:.3}", warm.p50()),
        format!("{:.3}", warm.p90()),
        format!("{:.3}", warm.p99()),
        format!("{:.3}", warm.max()),
    ]);
    t.row(&[
        format!("{host_cap_gb} GB"),
        format!("{:.3}", cold.p50()),
        format!("{:.3}", cold.p90()),
        format!("{:.3}", cold.p99()),
        format!("{:.3}", cold.max()),
    ]);
    t.print();

    let delta = cold.p90() - warm.p90();
    println!(
        "\ntail-latency delta at p90: {delta:+.3}s \
         ({})",
        if delta > 1.0 {
            "tenant B's demotions evicted A's warm copies — A re-scaled cold from SSD"
        } else {
            "no contention: A's warm copies survived in host memory"
        }
    );
}
